//! Sharded embedding service — *measured* scale-out inference (paper
//! §VII's "distributed inference" direction, grounded in Lui et al.'s
//! capacity-driven scale-out study): RMC2-class tables exceed one
//! node's DRAM comfort zone, so production shards embedding tables
//! across nodes; a leader fans SLS requests out, shards serve the rows
//! they own, and the leader runs the dense/interaction/top-MLP stack on
//! the gathered vectors.
//!
//! Placement is a first-class plan (`runtime::placement`): each table
//! is either owned whole by one shard, **replicated** on several (reads
//! load-balanced across byte-identical copies), or **row-range split**
//! across shards so one huge table no longer pins a single executor's
//! memory. `NativeModel::take_table_rows` moves the encoded rows
//! (f32/f16/int8 per `--dtype`) out of the leader and
//! `placement::slice_tables` cuts them into per-shard byte stores, so
//! the capacity split (and the replication overhead, and the quantized
//! shrink) is real memory, not a modeled number. An optional hot-row [`EmbeddingCache`] on the
//! leader (`runtime::row_cache`) short-circuits remote lookups for hot
//! rows — viable exactly because of the paper's Fig-14 locality
//! spectrum — and reports measured hit rates next to
//! `simulator::embedding_cache`'s predictions.
//!
//! # Determinism contract
//!
//! A sharded run is bit-identical to the single-node `run_rmc` under
//! **any** valid placement — whole, split, replicated, any shard
//! count, cache on or off (enforced by `tests/prop_invariants.rs`):
//!
//! * A table owned whole by one shard (or replicated) pools remotely:
//!   the executor accumulates each (table, sample) tile in ascending
//!   lookup order through the shared `sls_axpy_bytes` step (decoding
//!   quantized rows exactly like the single-node `sls_tiles` kernel).
//!   Replicas hold byte-identical rows, so replica choice changes
//!   *where* bytes come from, never which bytes are summed.
//! * A row-split table's tile may need rows from several shards, and
//!   float addition is not associative — so split tables are never
//!   pooled shard-side. The leader fetches the (batch-deduplicated)
//!   raw rows and pools them itself in the same ascending-lookup
//!   order. Moving a row between shards relocates bytes; the reduction
//!   order is pinned by the leader.
//! * A cache hit returns a byte-exact copy of the row the shard would
//!   have served, and the cache path reuses the leader-side pooling
//!   above for every table.
//! * The leader's bottom/interaction/top stack is the single-node
//!   optimized engine itself (`bottom_mlp_into` / `interact_and_top`),
//!   which is bit-stable in its thread count by the engine contract.
//!
//! Overlap: the leader computes the bottom MLP while shards gather, so
//! scale-out latency hides the dense tower behind the SLS fan-out.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::{mpsc, Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

use anyhow::{anyhow, ensure};

use super::native::{
    sls_axpy_bytes, Engine, EngineKind, ExecOptions, NativeModel, ScratchArena, TableDtype,
    TableRows,
};
use super::placement::{
    row_owners, slice_tables, Placement, PlacementMode, PlacementPlanner, ShardSegments,
    TablePlacement, TableSkew,
};
use super::row_cache::{row_key, EmbeddingCache};
use crate::config::RmcConfig;
use crate::util::json::{num, obj};
use crate::util::Json;

/// Batches of measured traffic an `--placement auto` service observes
/// before replanning from the recorded skew.
pub const AUTO_REPLAN_AFTER_BATCHES: u64 = 8;

/// Typed failure for a lookup whose row lives only on dead shard(s):
/// a Split row range owned by a killed executor, or a Replicated table
/// with no surviving replica. The leader surfaces it as a per-batch
/// error (downcastable from the `anyhow` chain) that the coordinator
/// converts into per-query failure + bounded retry — never a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardUnavailable {
    /// The dead shard the lookup routed to.
    pub shard: usize,
    /// The global table whose data was unreachable.
    pub table: usize,
}

impl fmt::Display for ShardUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "embedding shard {} unavailable (table {} has no surviving replica)",
            self.shard, self.table
        )
    }
}

impl std::error::Error for ShardUnavailable {}

// Poison-tolerant lock access: a panicked shard executor (or a caller
// panicking mid-snapshot) must not cascade-poison the leader's stats
// and topology locks — the guarded state is counters and an
// already-consistent topology, both safe to read after an unwind.
fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_tolerant<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn write_tolerant<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Cumulative per-stage breakdown of a service's lifetime (snapshot via
/// [`ShardedEmbeddingService::stats`]); the measured analogue of
/// `simulator::distributed::ShardedResult`.
#[derive(Debug, Clone, Default)]
pub struct ShardedStats {
    /// Shard executors (config, filled on snapshot).
    pub shards: usize,
    /// Hot-row cache capacity in rows (0 = cache disabled).
    pub cache_capacity_rows: usize,
    /// Placement policy in force (config, filled on snapshot).
    pub placement: PlacementMode,
    /// Embedding storage dtype name (config, filled on snapshot).
    pub dtype: &'static str,
    /// Forward passes served.
    pub batches: u64,
    /// Sum over batches of the *slowest* shard's gather/pool compute
    /// time (the critical-path shard, like the simulator's
    /// `shard_sls_ms`).
    pub shard_sls_ns: f64,
    /// Leader-side fan-out serialization, result copy/pooling, and
    /// non-overlapped wait slack — the stand-in for the simulator's
    /// `network_ms`. Disjoint from `shard_sls_ns`: the portion of the
    /// reply wait that is just the critical-path shard still computing
    /// (beyond what the bottom MLP overlapped) is charged to the shard,
    /// not double-counted here.
    pub gather_ns: f64,
    /// Leader bottom-MLP + interaction + top-MLP + CTR head time.
    pub leader_mlp_ns: f64,
    /// Hot-row cache lookups that short-circuited a remote fetch.
    pub cache_hits: u64,
    /// Weighted lookups that needed their row from a shard.
    pub cache_misses: u64,
    /// Rows actually shipped leader <- shards (deduplicated per batch).
    pub rows_fetched: u64,
    /// Weighted lookups routed to each shard (row ownership, with the
    /// batch's replica choices applied) — the measured lookup balance.
    pub shard_lookups: Vec<u64>,
    /// Of `shard_lookups`, the portion each shard served on behalf of
    /// a *replicated* table — the replica read split.
    pub replica_reads: Vec<u64>,
    /// Weighted lookups per global table — the skew signal the
    /// `PlacementPlanner` replans from.
    pub table_lookups: Vec<u64>,
    /// Embedding bytes owned per shard under the current plan
    /// (snapshot; replica copies included).
    pub shard_bytes: Vec<u64>,
    /// Placement replans applied (`--placement auto`).
    pub replans: u64,
    /// Shard executors currently alive (snapshot; `shards` minus the
    /// killed-and-not-restarted ones).
    pub shards_alive: usize,
    /// Shard executors killed by fault injection.
    pub shard_deaths: u64,
    /// Killed shards re-materialized from the parameter seed.
    pub shard_restarts: u64,
    /// Weighted lookups rerouted to a surviving replica because a copy
    /// in the table's replica set was dead — the measured failover
    /// traffic (degraded but bitwise-correct reads).
    pub failover_reads: u64,
}

fn add_vec(dst: &mut Vec<u64>, src: &[u64]) {
    if dst.len() < src.len() {
        dst.resize(src.len(), 0);
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

fn u64_arr(v: &[u64]) -> Json {
    Json::Arr(v.iter().map(|&x| num(x as f64)).collect())
}

impl ShardedStats {
    pub fn total_ns(&self) -> f64 {
        self.shard_sls_ns + self.gather_ns + self.leader_mlp_ns
    }

    /// Cache hit rate over weighted lookups (0 when no cache traffic).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.cache_hits as f64, self.cache_misses as f64);
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// max/mean of `shard_lookups` — 1.0 is a perfectly even routing
    /// split, `shards` is everything on one executor.
    pub fn lookup_imbalance(&self) -> f64 {
        let sum: u64 = self.shard_lookups.iter().sum();
        if self.shard_lookups.is_empty() || sum == 0 {
            return 1.0;
        }
        let mean = sum as f64 / self.shard_lookups.len() as f64;
        *self.shard_lookups.iter().max().unwrap() as f64 / mean
    }

    /// Machine-readable form (serve --json / benches).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("shards", num(self.shards as f64)),
            ("cache_capacity_rows", num(self.cache_capacity_rows as f64)),
            ("placement", Json::Str(self.placement.name().into())),
            ("dtype", Json::Str(self.dtype.into())),
            ("batches", num(self.batches as f64)),
            ("shard_sls_ns", num(self.shard_sls_ns)),
            ("gather_ns", num(self.gather_ns)),
            ("leader_mlp_ns", num(self.leader_mlp_ns)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_misses", num(self.cache_misses as f64)),
            ("cache_hit_rate", num(self.hit_rate())),
            ("rows_fetched", num(self.rows_fetched as f64)),
            ("shard_lookups", u64_arr(&self.shard_lookups)),
            ("lookup_imbalance", num(self.lookup_imbalance())),
            ("replica_reads", u64_arr(&self.replica_reads)),
            ("table_lookups", u64_arr(&self.table_lookups)),
            ("shard_bytes", u64_arr(&self.shard_bytes)),
            ("replans", num(self.replans as f64)),
            ("shards_alive", num(self.shards_alive as f64)),
            ("shard_deaths", num(self.shard_deaths as f64)),
            ("shard_restarts", num(self.shard_restarts as f64)),
            ("failover_reads", num(self.failover_reads as f64)),
        ])
    }
}

/// Table chunks owned by one shard executor (moved out of the leader
/// model): per global table, ascending `(row_lo, row bytes)` slices in
/// the table's storage dtype (f32/f16/int8 — rows ship and pool as the
/// exact encoded bytes, so quantized capacity savings are real memory).
struct ShardTables {
    segs: ShardSegments,
    emb_dim: usize,
    row_bytes: usize,
    dtype: TableDtype,
    lookups: usize,
}

impl ShardTables {
    /// Full copy of table `t` (only valid for tables this shard holds
    /// whole — the leader only sends `Pool` jobs for those).
    fn full(&self, t: usize) -> &[u8] {
        &self.segs[&t][0].1
    }

    /// The `row_bytes` encoded bytes of row `id` of table `t` (the
    /// leader only requests rows inside this shard's owned ranges).
    fn row(&self, t: usize, id: usize) -> &[u8] {
        let chunks = &self.segs[&t];
        let i = chunks.partition_point(|(lo, _)| *lo <= id) - 1;
        let (lo, data) = &chunks[i];
        let off = (id - lo) * self.row_bytes;
        &data[off..off + self.row_bytes]
    }
}

/// One fan-out request.
enum ShardJob {
    /// Pool the listed (whole-owned) tables' lookups; ids/weights are
    /// laid out (tables.len(), B, L) row-major in listed-table order;
    /// reply is the matching (tables.len(), B, E) pooled block.
    Pool {
        tables: Vec<usize>,
        ids: Vec<i32>,
        lwts: Vec<f32>,
        batch: usize,
        reply: mpsc::Sender<PoolReply>,
    },
    /// Fetch raw rows (row-split tables and cache-miss fills); reply
    /// rows in request order, `row_bytes` encoded bytes each.
    Rows { wants: Vec<(usize, i32)>, reply: mpsc::Sender<RowsReply> },
}

struct PoolReply {
    pooled: Vec<f32>,
    compute_ns: u64,
}

struct RowsReply {
    rows: Vec<u8>,
    compute_ns: u64,
}

/// Shard executor loop: owns its table chunks for the topology's
/// lifetime; exits when the leader drops its sender.
fn shard_loop(st: ShardTables, rx: mpsc::Receiver<ShardJob>) {
    let emb = st.emb_dim;
    let rb = st.row_bytes;
    while let Ok(job) = rx.recv() {
        match job {
            ShardJob::Pool { tables, ids, lwts, batch, reply } => {
                let t0c = Instant::now();
                let l = st.lookups;
                let mut pooled = vec![0.0f32; tables.len() * batch * emb];
                for (k, &t) in tables.iter().enumerate() {
                    let table = st.full(t);
                    for s in 0..batch {
                        let q = k * batch + s;
                        let acc = &mut pooled[q * emb..(q + 1) * emb];
                        let base = q * l;
                        // Ascending-lookup accumulation through the
                        // shared sls_axpy_bytes step — byte-for-byte
                        // the single-node sls_tiles reduction (ids are
                        // leader-prescanned, so indexing is in-bounds).
                        for li in 0..l {
                            let w = lwts[base + li];
                            if w == 0.0 {
                                continue;
                            }
                            let start = ids[base + li] as usize * rb;
                            sls_axpy_bytes(acc, w, &table[start..start + rb], st.dtype);
                        }
                    }
                }
                let _ = reply
                    .send(PoolReply { pooled, compute_ns: t0c.elapsed().as_nanos() as u64 });
            }
            ShardJob::Rows { wants, reply } => {
                let t0c = Instant::now();
                let mut rows = vec![0u8; wants.len() * rb];
                for (k, (t, id)) in wants.iter().enumerate() {
                    rows[k * rb..(k + 1) * rb].copy_from_slice(st.row(*t, *id as usize));
                }
                let _ =
                    reply.send(RowsReply { rows, compute_ns: t0c.elapsed().as_nanos() as u64 });
            }
        }
    }
}

/// The live shard topology: the plan plus the executors realizing it.
/// Swapped whole on an auto replan (behind the service's `RwLock`).
/// A killed shard keeps its slot (`None` sender) so shard indices stay
/// stable for the plan and the stats vectors; a restart refills it.
struct Topology {
    plan: Placement,
    senders: Vec<Option<mpsc::Sender<ShardJob>>>,
    joins: Vec<Option<std::thread::JoinHandle<()>>>,
    shard_bytes: Vec<usize>,
}

impl Topology {
    /// Slice `tables` per `plan` and spawn one executor per shard.
    fn spawn(
        plan: Placement,
        tables: Vec<TableRows>,
        cfg: &RmcConfig,
        rows: usize,
        dtype: TableDtype,
    ) -> Topology {
        let row_bytes = dtype.row_bytes(cfg.emb_dim);
        let shard_bytes = plan.shard_bytes(rows, row_bytes);
        let stores = slice_tables(tables, &plan, row_bytes);
        let mut senders = Vec::with_capacity(plan.shards);
        let mut joins = Vec::with_capacity(plan.shards);
        for (i, segs) in stores.into_iter().enumerate() {
            let st = ShardTables {
                segs,
                emb_dim: cfg.emb_dim,
                row_bytes,
                dtype,
                lookups: cfg.lookups,
            };
            let (tx, join) = spawn_executor(i, st);
            senders.push(Some(tx));
            joins.push(Some(join));
        }
        Topology { plan, senders, joins, shard_bytes }
    }

    /// Whether shard `s` has a live executor.
    fn alive(&self, s: usize) -> bool {
        self.senders.get(s).is_some_and(Option::is_some)
    }

    /// Live executor count.
    fn alive_count(&self) -> usize {
        self.senders.iter().filter(|s| s.is_some()).count()
    }

    /// Kill shard `s`: drop its sender (the executor drains queued
    /// jobs — their replies still arrive — then exits) and reap the
    /// thread. Returns false if the shard was already dead or the
    /// index is out of range.
    fn kill(&mut self, s: usize) -> bool {
        match self.senders.get_mut(s) {
            Some(slot) if slot.is_some() => *slot = None,
            _ => return false,
        }
        if let Some(j) = self.joins[s].take() {
            let _ = j.join();
        }
        true
    }

    /// Refill a killed shard's slot with a freshly materialized
    /// executor.
    fn respawn(&mut self, s: usize, st: ShardTables) {
        debug_assert!(self.senders[s].is_none(), "respawn of a live shard");
        let (tx, join) = spawn_executor(s, st);
        self.senders[s] = Some(tx);
        self.joins[s] = Some(join);
    }

    /// Close the executor channels and reap the threads.
    fn shutdown(&mut self) {
        self.senders.clear();
        for j in self.joins.drain(..).flatten() {
            let _ = j.join();
        }
    }
}

fn spawn_executor(
    i: usize,
    st: ShardTables,
) -> (mpsc::Sender<ShardJob>, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let join = std::thread::Builder::new()
        .name(format!("emb-shard-{i}"))
        .spawn(move || shard_loop(st, rx))
        .expect("spawn shard executor");
    (tx, join)
}

/// Placement-aware sharded SLS execution with an optional leader
/// hot-row cache; see the module docs for topology and the determinism
/// contract.
pub struct ShardedEmbeddingService {
    /// MLPs + interaction only — `take_table_rows` moved the rows out.
    leader: NativeModel,
    /// Leader intra-op engine for the dense stack (shared with the
    /// owning backend when co-located services would otherwise
    /// multiply thread pools).
    engine: Arc<Engine>,
    topo: RwLock<Topology>,
    /// Parameter seed the model was built with — lets an auto replan
    /// re-materialize the tables deterministically.
    seed: u64,
    /// Embedding-table storage dtype (f32/f16/int8) — fixed at build,
    /// shared by shards, replicas, the row transport, and the cache.
    dtype: TableDtype,
    /// Replans enabled (placement auto, not a pinned custom plan).
    auto_replan: bool,
    planner: PlacementPlanner,
    cache: Option<EmbeddingCache>,
    /// Serializes replans (snapshot-compute-swap); batches keep running
    /// under the topology read lock meanwhile.
    replan_gate: Mutex<()>,
    stats: Mutex<ShardedStats>,
}

impl ShardedEmbeddingService {
    /// Build the (cfg, seed) model — parameter-identical to
    /// `NativeModel::new(cfg, seed)` — and place its tables across
    /// `opts.shards` executors per `opts.placement`. `opts.cache_rows
    /// > 0` adds the leader hot-row cache sized as that fraction of
    /// total table rows.
    pub fn new(cfg: &RmcConfig, seed: u64, opts: ExecOptions) -> anyhow::Result<Self> {
        Self::from_model(NativeModel::with_dtype(cfg, seed, opts.dtype), seed, opts)
    }

    /// Build by preset name (`config::all_rmc`).
    pub fn from_name(name: &str, seed: u64, opts: ExecOptions) -> anyhow::Result<Self> {
        Self::from_model(NativeModel::from_name_dtype(name, seed, opts.dtype)?, seed, opts)
    }

    /// Consume a built model: move its tables out to the shard
    /// executors and keep the MLP stack as the leader (the service
    /// spawns its own leader engine; see `from_model_with_engine` to
    /// share one). `seed` must be the seed `model` was built with (it
    /// re-materializes the tables on an auto replan).
    pub fn from_model(model: NativeModel, seed: u64, opts: ExecOptions) -> anyhow::Result<Self> {
        let engine =
            Arc::new(Engine::new(ExecOptions { threads: opts.threads, ..Default::default() }));
        Self::from_model_with_engine(model, seed, opts, engine)
    }

    /// Like `from_model` but running the leader's dense stack on an
    /// already-constructed engine — `NativeBackend` passes its own, so
    /// a multi-tenant mix of sharded services contends on one intra-op
    /// pool instead of spawning one per model.
    pub fn from_model_with_engine(
        model: NativeModel,
        seed: u64,
        opts: ExecOptions,
        engine: Arc<Engine>,
    ) -> anyhow::Result<Self> {
        let cfg = model.cfg();
        ensure!(cfg.num_tables > 0, "{}: no embedding tables to shard", cfg.name);
        let planner =
            PlacementPlanner::new(opts.shards, opts.placement, opts.replicate_hot);
        // No measured skew yet: the initial plan is the static
        // byte-balanced one (for `whole`, the PR-4 table-wise layout).
        // Byte budgets see the model's *stored* row size, so quantized
        // dtypes fit more rows under the same capacity.
        let row_bytes = model.dtype().row_bytes(cfg.emb_dim);
        let plan = planner.plan(cfg.num_tables, model.rows(), row_bytes, &[])?;
        Self::with_plan_inner(model, seed, opts, engine, planner, plan, true)
    }

    /// Build with an explicit, possibly hand-crafted plan (conformance
    /// property tests exercise random splits/replica sets through
    /// this). The plan is pinned: auto replanning is disabled.
    pub fn with_plan(
        cfg: &RmcConfig,
        seed: u64,
        opts: ExecOptions,
        plan: Placement,
    ) -> anyhow::Result<Self> {
        let model = NativeModel::with_dtype(cfg, seed, opts.dtype);
        let engine =
            Arc::new(Engine::new(ExecOptions { threads: opts.threads, ..Default::default() }));
        let planner =
            PlacementPlanner::new(plan.shards, opts.placement, opts.replicate_hot);
        Self::with_plan_inner(model, seed, opts, engine, planner, plan, false)
    }

    fn with_plan_inner(
        mut model: NativeModel,
        seed: u64,
        opts: ExecOptions,
        engine: Arc<Engine>,
        planner: PlacementPlanner,
        plan: Placement,
        from_planner: bool,
    ) -> anyhow::Result<Self> {
        ensure!(
            opts.engine == EngineKind::Optimized,
            "the sharded service runs the optimized leader stack; \
             --engine reference is a single-node A/B baseline"
        );
        ensure!(
            engine.kind() == EngineKind::Optimized,
            "the sharded leader stack requires an optimized engine"
        );
        opts.validate()?;
        let cfg = model.cfg().clone();
        let rows = model.rows();
        let dtype = model.dtype();
        let row_bytes = dtype.row_bytes(cfg.emb_dim);
        plan.validate(cfg.num_tables, rows)?;

        let cache = if opts.cache_rows > 0.0 {
            let total_rows = cfg.num_tables * rows;
            let cap = ((total_rows as f64 * opts.cache_rows) as usize).max(16);
            // Per-table hit counters feed the planner's skew signal.
            // Entries are encoded rows, so a quantized dtype shrinks
            // the cache footprint at the same row capacity.
            Some(EmbeddingCache::with_tables(cap, row_bytes, cfg.num_tables))
        } else {
            None
        };
        let topo = Topology::spawn(plan, model.take_table_rows(), &cfg, rows, dtype);
        Ok(ShardedEmbeddingService {
            leader: model,
            engine,
            topo: RwLock::new(topo),
            seed,
            dtype,
            auto_replan: from_planner && opts.placement == PlacementMode::Auto,
            planner,
            cache,
            replan_gate: Mutex::new(()),
            stats: Mutex::new(ShardedStats::default()),
        })
    }

    pub fn cfg(&self) -> &RmcConfig {
        self.leader.cfg()
    }

    /// Rows materialized per embedding table.
    pub fn rows(&self) -> usize {
        self.leader.rows()
    }

    /// Embedding-table storage dtype across shards, cache, and
    /// transport.
    pub fn dtype(&self) -> TableDtype {
        self.dtype
    }

    /// Shard executors in the topology (killed slots included — shard
    /// indices stay stable across kill/restart).
    pub fn shards(&self) -> usize {
        read_tolerant(&self.topo).plan.shards
    }

    /// Per-shard liveness snapshot (`false` = killed, not restarted).
    pub fn alive_shards(&self) -> Vec<bool> {
        let topo = read_tolerant(&self.topo);
        (0..topo.plan.shards).map(|s| topo.alive(s)).collect()
    }

    /// Snapshot of the placement plan in force.
    pub fn placement(&self) -> Placement {
        read_tolerant(&self.topo).plan.clone()
    }

    /// Embedding bytes owned by each shard — the per-node capacity the
    /// leader no longer pays (replica copies included).
    pub fn shard_bytes(&self) -> Vec<usize> {
        read_tolerant(&self.topo).shard_bytes.clone()
    }

    /// Leader-resident parameter bytes (MLPs only; tables moved out).
    pub fn leader_param_bytes(&self) -> usize {
        self.leader.param_bytes()
    }

    pub fn cache(&self) -> Option<&EmbeddingCache> {
        self.cache.as_ref()
    }

    /// Snapshot of the cumulative per-stage breakdown.
    pub fn stats(&self) -> ShardedStats {
        let mut s = lock_tolerant(&self.stats).clone();
        let topo = read_tolerant(&self.topo);
        s.shards = topo.plan.shards;
        s.shards_alive = topo.alive_count();
        s.placement = self.planner.mode;
        s.dtype = self.dtype.name();
        s.cache_capacity_rows = self.cache.as_ref().map_or(0, |c| c.capacity_rows());
        s.shard_bytes = topo.shard_bytes.iter().map(|&b| b as u64).collect();
        s.shard_lookups.resize(topo.plan.shards.max(s.shard_lookups.len()), 0);
        s.replica_reads.resize(topo.plan.shards.max(s.replica_reads.len()), 0);
        s.table_lookups.resize(self.cfg().num_tables, 0);
        s
    }

    /// Zero the breakdown and drop cached rows (bench hygiene between
    /// sweep points).
    pub fn reset_stats(&self) {
        *lock_tolerant(&self.stats) = ShardedStats::default();
        if let Some(c) = &self.cache {
            c.clear();
        }
    }

    /// Fault injection: kill shard `shard`'s executor. Its queued jobs
    /// drain (in-flight batches keep their replies) before the thread
    /// is reaped; afterwards Replicated tables it held fail over to
    /// surviving replicas and Split row ranges it owned alone surface
    /// [`ShardUnavailable`] per batch. Returns false when the index is
    /// out of range or the shard is already dead.
    pub fn kill_shard(&self, shard: usize) -> bool {
        // Same gate as replans: kill vs replan vs restart serialize, so
        // a concurrent plan swap can never resurrect a killed slot.
        let _gate = lock_tolerant(&self.replan_gate);
        let killed = write_tolerant(&self.topo).kill(shard);
        if killed {
            lock_tolerant(&self.stats).shard_deaths += 1;
        }
        killed
    }

    /// Fault recovery: re-materialize a killed shard's table chunks
    /// from the parameter seed (byte-identical to the originals, same
    /// determinism argument as auto replans) and rejoin it to the
    /// topology under the write lock. Returns false when the shard is
    /// alive or the index is out of range.
    pub fn restart_shard(&self, shard: usize) -> anyhow::Result<bool> {
        let _gate = lock_tolerant(&self.replan_gate);
        // The gate serializes every topology mutation, so the plan
        // snapshot below cannot go stale before the write lock.
        let plan = {
            let topo = read_tolerant(&self.topo);
            if shard >= topo.plan.shards || topo.alive(shard) {
                return Ok(false);
            }
            topo.plan.clone()
        };
        let cfg = self.cfg().clone();
        let row_bytes = self.dtype.row_bytes(cfg.emb_dim);
        let tables = NativeModel::with_dtype(&cfg, self.seed, self.dtype).take_table_rows();
        let mut stores = slice_tables(tables, &plan, row_bytes);
        let segs = std::mem::take(&mut stores[shard]);
        let st = ShardTables {
            segs,
            emb_dim: cfg.emb_dim,
            row_bytes,
            dtype: self.dtype,
            lookups: cfg.lookups,
        };
        write_tolerant(&self.topo).respawn(shard, st);
        lock_tolerant(&self.stats).shard_restarts += 1;
        Ok(true)
    }

    /// Recompute the plan from the skew measured so far and swap the
    /// topology if it changed. Returns whether a new plan was applied.
    /// `--placement auto` calls this automatically after
    /// [`AUTO_REPLAN_AFTER_BATCHES`]; benches may call it directly.
    pub fn replan_from_stats(&self) -> anyhow::Result<bool> {
        let _gate = lock_tolerant(&self.replan_gate);
        let cfg = self.cfg().clone();
        let rows = self.rows();
        let mut skew: Vec<TableSkew> = {
            let s = lock_tolerant(&self.stats);
            (0..cfg.num_tables)
                .map(|t| TableSkew {
                    lookups: s.table_lookups.get(t).copied().unwrap_or(0),
                    cache_hits: 0,
                })
                .collect()
        };
        if let Some(cache) = &self.cache {
            for (t, hits) in cache.table_hits().into_iter().enumerate() {
                skew[t].cache_hits = hits;
            }
        }
        let plan =
            self.planner.plan(cfg.num_tables, rows, self.dtype.row_bytes(cfg.emb_dim), &skew)?;
        let dead: Vec<usize> = {
            let topo = read_tolerant(&self.topo);
            if plan == topo.plan {
                return Ok(false);
            }
            (0..topo.plan.shards).filter(|&s| !topo.alive(s)).collect()
        };
        // Re-materialize the tables (deterministic from (cfg, seed) —
        // parameter init is pure) and swap executors under the write
        // lock. In-flight batches finished under the old topology keep
        // their replies: queued jobs drain before an executor exits.
        let tables = NativeModel::with_dtype(&cfg, self.seed, self.dtype).take_table_rows();
        let mut fresh = Topology::spawn(plan, tables, &cfg, rows, self.dtype);
        // A replan changes the layout, not the fleet's health: shards
        // that were killed stay killed (only an explicit restart event
        // revives them), so degraded-mode accounting never self-heals.
        for s in dead {
            fresh.kill(s);
        }
        {
            let mut topo = write_tolerant(&self.topo);
            std::mem::swap(&mut *topo, &mut fresh);
        }
        fresh.shutdown(); // the old topology
        lock_tolerant(&self.stats).replans += 1;
        Ok(true)
    }

    /// Forward pass through the sharded topology with a thread-local
    /// scratch arena. Input layout matches `NativeModel::run_rmc`:
    /// dense (B, Dd), ids (T, B, L), lwts (T, B, L), row-major.
    pub fn run_rmc(&self, dense: &[f32], ids: &[i32], lwts: &[f32]) -> anyhow::Result<Vec<f32>> {
        thread_local! {
            static SCRATCH: RefCell<ScratchArena> = RefCell::new(ScratchArena::new());
        }
        SCRATCH.with(|s| {
            let mut arena = s.borrow_mut();
            self.run_rmc_into(&mut arena, dense, ids, lwts).map(|o| o.to_vec())
        })
    }

    /// Allocation-lean forward pass: the returned CTR slice borrows the
    /// arena (valid until the arena's next use).
    pub fn run_rmc_into<'a>(
        &self,
        arena: &'a mut ScratchArena,
        dense: &[f32],
        ids: &[i32],
        lwts: &[f32],
    ) -> anyhow::Result<&'a [f32]> {
        let batch = self.leader.validate(dense, ids, lwts)?;
        // Prescan on the leader: shard executors then gather
        // unconditionally (an out-of-range id never crosses a channel).
        self.leader.prescan_ids(ids, lwts, batch)?;
        self.leader.ensure_forward_buffers(arena, batch);

        let emb = self.cfg().emb_dim;
        let per_table = batch * self.cfg().lookups;
        let mut delta = ShardedStats::default();

        // --- fan out ---------------------------------------------------
        // Replica load-balancing seeds from the lifetime routing counts
        // so successive batches spread over the copies.
        let base_loads = {
            let s = lock_tolerant(&self.stats);
            s.shard_lookups.clone()
        };
        let t_fan = Instant::now();
        let mut pending = {
            let topo = read_tolerant(&self.topo);
            self.fan_out(&topo, ids, lwts, batch, per_table, &base_loads, &mut delta)?
        };
        delta.gather_ns += t_fan.elapsed().as_nanos() as f64;

        // --- leader bottom MLP overlaps the shard gathers --------------
        let t_mlp = Instant::now();
        let in_ping = self.leader.bottom_mlp_into(&self.engine, arena, dense, batch);
        let bottom_ns = t_mlp.elapsed().as_nanos() as f64;
        delta.leader_mlp_ns += bottom_ns;

        // --- gather ----------------------------------------------------
        let t_gather = Instant::now();
        let mut max_shard_ns = 0u64;
        for req in pending.pooled.drain(..) {
            let reply = req
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("embedding shard {} died mid-request", req.shard))?;
            for (k, &t) in req.tables.iter().enumerate() {
                arena.emb[t * batch * emb..(t + 1) * batch * emb]
                    .copy_from_slice(&reply.pooled[k * batch * emb..(k + 1) * batch * emb]);
            }
            max_shard_ns = max_shard_ns.max(reply.compute_ns);
        }
        let rb = self.dtype.row_bytes(emb);
        for req in pending.rows.drain(..) {
            let reply = req
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("embedding shard {} died mid-request", req.shard))?;
            for (k, (t, id)) in req.wants.iter().enumerate() {
                let row = &reply.rows[k * rb..(k + 1) * rb];
                let key = row_key(*t, *id as u32);
                if let Some(cache) = &self.cache {
                    cache.insert(key, row);
                }
                pending.rowmap.insert(key, row.to_vec());
            }
            delta.rows_fetched += req.wants.len() as u64;
            max_shard_ns = max_shard_ns.max(reply.compute_ns);
        }
        // Leader-side pooling for row-resolved tables (split tables,
        // and every table in cache mode) — the same ascending-lookup
        // sls_axpy_bytes accumulation (dequantizing in place for
        // quantized dtypes) as the single-node sls_tiles, so split and
        // cached execution stay bit-identical per dtype.
        for &t in &pending.fetched {
            for s in 0..batch {
                let q = t * batch + s;
                let acc = &mut arena.emb[q * emb..(q + 1) * emb];
                acc.fill(0.0);
                let base = q * self.cfg().lookups;
                for li in 0..self.cfg().lookups {
                    let w = lwts[base + li];
                    if w == 0.0 {
                        continue;
                    }
                    let key = row_key(t, ids[base + li] as u32);
                    let row = &pending.rowmap[&key];
                    // A leftover empty placeholder would pool zeros
                    // silently; every queued want must have been
                    // resolved by the fetch loop.
                    debug_assert_eq!(row.len(), rb, "unresolved row fetch pooled");
                    sls_axpy_bytes(acc, w, row, self.dtype);
                }
            }
        }
        delta.shard_sls_ns += max_shard_ns as f64;
        // Keep gather disjoint from shard compute (the simulator keeps
        // shard_sls_ms and network_ms disjoint the same way): the part
        // of the reply wait where the critical-path shard was still
        // computing — beyond what the bottom MLP already overlapped —
        // is shard time, not fan-out/gather overhead.
        let gather_elapsed = t_gather.elapsed().as_nanos() as f64;
        let waited_on_compute = (max_shard_ns as f64 - bottom_ns).clamp(0.0, gather_elapsed);
        delta.gather_ns += gather_elapsed - waited_on_compute;

        // --- leader interaction + top MLP + CTR head -------------------
        let t_top = Instant::now();
        self.leader.interact_and_top(&self.engine, arena, in_ping, batch, None);
        delta.leader_mlp_ns += t_top.elapsed().as_nanos() as f64;

        let batches_done = {
            let mut s = lock_tolerant(&self.stats);
            s.batches += 1;
            s.shard_sls_ns += delta.shard_sls_ns;
            s.gather_ns += delta.gather_ns;
            s.leader_mlp_ns += delta.leader_mlp_ns;
            s.cache_hits += delta.cache_hits;
            s.cache_misses += delta.cache_misses;
            s.rows_fetched += delta.rows_fetched;
            s.failover_reads += delta.failover_reads;
            add_vec(&mut s.shard_lookups, &delta.shard_lookups);
            add_vec(&mut s.replica_reads, &delta.replica_reads);
            add_vec(&mut s.table_lookups, &delta.table_lookups);
            s.batches
        };
        // Auto placement: after a warmup of measured traffic, replan
        // from the recorded skew (once; further replans on explicit
        // `replan_from_stats` calls). Numerics are placement-invariant,
        // so a replan can never change results — only balance.
        if self.auto_replan && batches_done == AUTO_REPLAN_AFTER_BATCHES {
            self.replan_from_stats()?;
        }
        Ok(&arena.out[..batch])
    }

    /// Route one batch: whole/replicated tables pool remotely on a
    /// (deterministically) chosen replica, split tables and cache-mode
    /// tables fetch deduplicated raw rows for leader-side pooling.
    #[allow(clippy::too_many_arguments)]
    fn fan_out(
        &self,
        topo: &Topology,
        ids: &[i32],
        lwts: &[f32],
        batch: usize,
        per_table: usize,
        base_loads: &[u64],
        delta: &mut ShardedStats,
    ) -> anyhow::Result<Pending> {
        let num_tables = self.cfg().num_tables;
        let shards = topo.plan.shards;
        let rb = self.dtype.row_bytes(self.cfg().emb_dim);
        delta.shard_lookups = vec![0; shards];
        delta.replica_reads = vec![0; shards];
        delta.table_lookups = vec![0; num_tables];

        // Weighted (non-padding) lookups per table: the routing unit
        // for balance accounting and the planner's skew signal.
        for t in 0..num_tables {
            let base = t * per_table;
            delta.table_lookups[t] =
                lwts[base..base + per_table].iter().filter(|w| **w != 0.0).count() as u64;
        }
        // Replica choice per replicated table: the *surviving* copy
        // with the least routed load so far (lifetime + this batch),
        // lowest index on ties. A pure function of placement, liveness,
        // and traffic counts — no timing — so it is deterministic for a
        // given batch + fault sequence; and since replicas are
        // byte-identical, the choice (failover included) can never
        // affect numerics. A table whose every replica is dead is a
        // typed per-batch error, not a panic.
        let load = |s: usize, d: &ShardedStats| {
            base_loads.get(s).copied().unwrap_or(0) + d.shard_lookups[s]
        };
        let choose_replica = |t: usize, reps: &[usize], d: &ShardedStats| {
            reps.iter()
                .copied()
                .filter(|&s| topo.alive(s))
                .min_by_key(|&s| (load(s, d), s))
                .ok_or_else(|| {
                    anyhow::Error::new(ShardUnavailable { shard: reps[0], table: t })
                })
        };

        let mut pool_sets: Vec<Vec<usize>> = vec![Vec::new(); shards];
        let mut wants: Vec<Vec<(usize, i32)>> = vec![Vec::new(); shards];
        let mut rowmap: HashMap<u64, Vec<u8>> = HashMap::new();
        let mut fetched: Vec<usize> = Vec::new();
        let mut rowbuf = vec![0u8; rb];
        let cache_mode = self.cache.is_some();

        for t in 0..num_tables {
            let tp = &topo.plan.tables[t];
            let replicated = matches!(tp, TablePlacement::Replicated(r) if r.len() > 1);
            // Whole-owned tables pool remotely — unless the cache is
            // on, where every table resolves row-wise so hits can
            // short-circuit shard traffic.
            if !cache_mode {
                if let TablePlacement::Replicated(reps) = tp {
                    let r = choose_replica(t, reps, delta)?;
                    pool_sets[r].push(t);
                    delta.shard_lookups[r] += delta.table_lookups[t];
                    if replicated {
                        delta.replica_reads[r] += delta.table_lookups[t];
                        // Failover accounting: these reads only landed
                        // here because a copy in the set is dead.
                        if reps.iter().any(|&s| !topo.alive(s)) {
                            delta.failover_reads += delta.table_lookups[t];
                        }
                    }
                    continue;
                }
            }
            // Row-resolved path: split tables always; every table in
            // cache mode. Probe the cache (if any) per weighted lookup
            // in sequential order — a row missed earlier in the batch
            // counts as a hit on re-encounter, matching the simulator's
            // probe-then-insert stream — and queue the misses to the
            // owning shard (least-loaded replica for replicated
            // tables, fixed per batch).
            fetched.push(t);
            let (table_replica, replica_failover) = match tp {
                TablePlacement::Replicated(reps) if cache_mode => (
                    Some(choose_replica(t, reps, delta)?),
                    reps.iter().any(|&s| !topo.alive(s)),
                ),
                _ => (None, false),
            };
            let base_t = t * per_table;
            for (&id, &w) in
                ids[base_t..base_t + per_table].iter().zip(&lwts[base_t..base_t + per_table])
            {
                if w == 0.0 {
                    continue;
                }
                // Routing accounting: every weighted lookup's row is
                // owned somewhere, whether or not the cache ends up
                // serving the bytes. A split row range owned only by a
                // dead shard has nowhere to fail over to — typed error.
                let owner = match table_replica {
                    Some(r) => r,
                    None => {
                        let owner = row_owners(&topo.plan, t, id as usize)[0];
                        if !topo.alive(owner) {
                            return Err(anyhow::Error::new(ShardUnavailable {
                                shard: owner,
                                table: t,
                            }));
                        }
                        owner
                    }
                };
                delta.shard_lookups[owner] += 1;
                if replicated {
                    delta.replica_reads[owner] += 1;
                    if replica_failover {
                        delta.failover_reads += 1;
                    }
                }
                let key = row_key(t, id as u32);
                if rowmap.contains_key(&key) {
                    // Resolved earlier in this batch (cache hit, or a
                    // miss already queued): sequentially it would be
                    // resident by now.
                    if cache_mode {
                        delta.cache_hits += 1;
                    }
                    continue;
                }
                if cache_mode {
                    if let Some(cache) = &self.cache {
                        if cache.probe_into(key, &mut rowbuf) {
                            delta.cache_hits += 1;
                            rowmap.insert(key, rowbuf.clone());
                            continue;
                        }
                    }
                    delta.cache_misses += 1;
                }
                wants[owner].push((t, id));
                // Placeholder marks the fetch as queued; the gather
                // overwrites it with the shard's bytes.
                rowmap.insert(key, Vec::new());
            }
        }

        let mut pooled = Vec::new();
        for (i, tables) in pool_sets.into_iter().enumerate() {
            if tables.is_empty() {
                continue;
            }
            let mut sids = Vec::with_capacity(tables.len() * per_table);
            let mut slwts = Vec::with_capacity(tables.len() * per_table);
            for &t in &tables {
                sids.extend_from_slice(&ids[t * per_table..(t + 1) * per_table]);
                slwts.extend_from_slice(&lwts[t * per_table..(t + 1) * per_table]);
            }
            let (reply_tx, reply_rx) = mpsc::channel();
            topo.senders[i]
                .as_ref()
                .ok_or(ShardUnavailable { shard: i, table: tables[0] })?
                .send(ShardJob::Pool {
                    tables: tables.clone(),
                    ids: sids,
                    lwts: slwts,
                    batch,
                    reply: reply_tx,
                })
                .map_err(|_| anyhow!("embedding shard {i} died"))?;
            pooled.push(PoolRequest { shard: i, tables, reply_rx });
        }
        let mut rows = Vec::new();
        for (i, want) in wants.into_iter().enumerate() {
            if want.is_empty() {
                continue;
            }
            let (reply_tx, reply_rx) = mpsc::channel();
            topo.senders[i]
                .as_ref()
                .ok_or(ShardUnavailable { shard: i, table: want[0].0 })?
                .send(ShardJob::Rows { wants: want.clone(), reply: reply_tx })
                .map_err(|_| anyhow!("embedding shard {i} died"))?;
            rows.push(RowsRequest { shard: i, wants: want, reply_rx });
        }
        Ok(Pending { pooled, rows, rowmap, fetched })
    }
}

/// One outstanding remote-pool request.
struct PoolRequest {
    shard: usize,
    /// Global table indices, in the pooled block's layout order.
    tables: Vec<usize>,
    reply_rx: mpsc::Receiver<PoolReply>,
}

/// One outstanding raw-row fetch.
struct RowsRequest {
    shard: usize,
    wants: Vec<(usize, i32)>,
    reply_rx: mpsc::Receiver<RowsReply>,
}

/// In-flight fan-out state between send and gather.
struct Pending {
    pooled: Vec<PoolRequest>,
    rows: Vec<RowsRequest>,
    /// Resolved rows (encoded bytes) for leader-side pooling, keyed by
    /// `row_key`.
    rowmap: HashMap<u64, Vec<u8>>,
    /// Tables (ascending) the leader pools from `rowmap`.
    fetched: Vec<usize>,
}

impl Drop for ShardedEmbeddingService {
    fn drop(&mut self) {
        self.topo.get_mut().unwrap_or_else(|e| e.into_inner()).shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelClass;
    use crate::runtime::placement::RowSegment;

    fn tiny_cfg() -> RmcConfig {
        RmcConfig {
            name: "tiny".into(),
            class: ModelClass::Rmc1,
            dense_dim: 4,
            bottom_mlp: vec![8, 4],
            top_mlp: vec![8],
            num_tables: 3,
            rows: 60,
            pjrt_rows: 60,
            emb_dim: 4,
            lookups: 5,
        }
    }

    fn tiny_inputs(cfg: &RmcConfig, batch: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        (
            super::super::golden_dense(batch, cfg.dense_dim),
            super::super::golden_ids(cfg.num_tables, batch, cfg.lookups, cfg.pjrt_rows),
            super::super::golden_lwts(cfg.num_tables, batch, cfg.lookups),
        )
    }

    fn opts(shards: usize, cache_rows: f64) -> ExecOptions {
        ExecOptions { shards, cache_rows, ..Default::default() }
    }

    fn opts_placed(
        shards: usize,
        cache_rows: f64,
        placement: PlacementMode,
        replicate_hot: f64,
    ) -> ExecOptions {
        ExecOptions { shards, cache_rows, placement, replicate_hot, ..Default::default() }
    }

    #[test]
    fn sharded_matches_single_node_bitwise() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 7);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 6);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        for shards in [1usize, 2, 3, 5] {
            let svc = ShardedEmbeddingService::new(&cfg, 7, opts(shards, 0.0)).unwrap();
            assert_eq!(svc.shards(), shards.min(cfg.num_tables), "table-count clamp");
            let got = svc.run_rmc(&dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "shards={shards} diverged from single-node");
        }
    }

    #[test]
    fn row_split_and_replicated_placements_match_single_node_bitwise() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 7);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 6);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        // Row placement at 5 shards > 3 tables: no clamp — row
        // granularity keeps every executor fed.
        for (shards, mode, rep) in [
            (2, PlacementMode::Rows, 0.0),
            (5, PlacementMode::Rows, 0.0),
            (4, PlacementMode::Rows, 0.5),
            (4, PlacementMode::Auto, 0.3),
        ] {
            let svc =
                ShardedEmbeddingService::new(&cfg, 7, opts_placed(shards, 0.0, mode, rep))
                    .unwrap();
            assert_eq!(svc.shards(), shards, "row placement must not clamp to table count");
            for _ in 0..2 {
                let got = svc.run_rmc(&dense, &ids, &lwts).unwrap();
                assert_eq!(want, got, "{}/{shards} shards diverged", mode.name());
            }
        }
    }

    #[test]
    fn explicit_plan_with_split_and_replicas_is_bitwise_and_balances_reads() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 11);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        let plan = Placement {
            shards: 2,
            tables: vec![
                TablePlacement::Replicated(vec![0, 1]),
                TablePlacement::Split(vec![
                    RowSegment { shard: 1, rows: (0, 17) },
                    RowSegment { shard: 0, rows: (17, 60) },
                ]),
                TablePlacement::Replicated(vec![1]),
            ],
        };
        let svc =
            ShardedEmbeddingService::with_plan(&cfg, 11, opts(2, 0.0), plan.clone()).unwrap();
        assert_eq!(svc.placement(), plan);
        for i in 0..4 {
            let got = svc.run_rmc(&dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "batch {i} diverged under custom plan");
        }
        let s = svc.stats();
        assert_eq!(s.batches, 4);
        // Table 0 is replicated: its reads are attributed as replica
        // reads somewhere.
        assert!(
            s.replica_reads.iter().sum::<u64>() > 0,
            "replicated table reads must be recorded: {:?}",
            s.replica_reads
        );
        // Every weighted lookup is routed somewhere.
        assert_eq!(
            s.shard_lookups.iter().sum::<u64>(),
            s.table_lookups.iter().sum::<u64>(),
            "routing accounting must cover all weighted lookups"
        );
        // The replica copy costs real bytes: shard 1 owns table 0 and
        // 2 whole plus 17 rows of table 1.
        let row_bytes = cfg.emb_dim * 4;
        assert_eq!(
            svc.shard_bytes(),
            &[
                (60 + 43) * row_bytes, // replica of t0 + t1 tail
                (60 + 17 + 60) * row_bytes,
            ]
        );
    }

    #[test]
    fn replica_reads_balance_across_copies() {
        // Every table fully replicated on both shards: within each
        // batch the least-loaded rule must hand at least one table to
        // each shard (after the first assignment, the other copy is
        // strictly less loaded), so both copies serve reads.
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 13);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        let plan = Placement {
            shards: 2,
            tables: (0..cfg.num_tables)
                .map(|_| TablePlacement::Replicated(vec![0, 1]))
                .collect(),
        };
        let svc =
            ShardedEmbeddingService::with_plan(&cfg, 13, opts(2, 0.0), plan).unwrap();
        for _ in 0..4 {
            assert_eq!(want, svc.run_rmc(&dense, &ids, &lwts).unwrap());
        }
        let s = svc.stats();
        assert!(
            s.replica_reads.iter().all(|&r| r > 0),
            "replica reads must spread over both copies: {:?}",
            s.replica_reads
        );
        // Full replication doubles the owned bytes on a 2-shard plan.
        let table_bytes = cfg.pjrt_rows * cfg.emb_dim * 4;
        assert_eq!(
            svc.shard_bytes().iter().sum::<usize>(),
            2 * cfg.num_tables * table_bytes
        );
    }

    #[test]
    fn cache_mode_is_bitwise_identical_and_hits_on_reuse() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 9);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        for mode in [PlacementMode::Whole, PlacementMode::Rows] {
            let svc =
                ShardedEmbeddingService::new(&cfg, 9, opts_placed(2, 0.5, mode, 0.0)).unwrap();
            let cold = svc.run_rmc(&dense, &ids, &lwts).unwrap();
            let warm = svc.run_rmc(&dense, &ids, &lwts).unwrap();
            assert_eq!(want, cold, "{}: cold cache diverged", mode.name());
            assert_eq!(want, warm, "{}: warm cache diverged", mode.name());
            let s = svc.stats();
            assert_eq!(s.batches, 2);
            assert!(s.cache_hits > 0, "repeat batch must hit: {s:?}");
            // The repeat batch's rows were all resolved leader-side.
            assert!(s.rows_fetched <= s.cache_misses, "fetches are deduplicated misses");
        }
    }

    #[test]
    fn capacity_split_is_real_and_covers_the_model() {
        let cfg = tiny_cfg();
        let svc = ShardedEmbeddingService::new(&cfg, 1, opts(2, 0.0)).unwrap();
        let table_bytes = cfg.pjrt_rows * cfg.emb_dim * 4;
        assert_eq!(svc.shard_bytes().iter().sum::<usize>(), cfg.num_tables * table_bytes);
        // 3 tables over 2 shards, whole placement: 2 + 1.
        assert_eq!(svc.shard_bytes(), &[2 * table_bytes, table_bytes]);
        assert_eq!(
            svc.placement().tables,
            vec![
                TablePlacement::Replicated(vec![0]),
                TablePlacement::Replicated(vec![0]),
                TablePlacement::Replicated(vec![1]),
            ]
        );
        // The leader really let go of the rows.
        assert_eq!(svc.leader_param_bytes(), 4 * cfg.fc_params() as usize);
        // Row placement balances within one row's bytes.
        let svc =
            ShardedEmbeddingService::new(&cfg, 1, opts_placed(2, 0.0, PlacementMode::Rows, 0.0))
                .unwrap();
        let bytes = svc.shard_bytes();
        assert_eq!(bytes.iter().sum::<usize>(), cfg.num_tables * table_bytes);
        let (max, min) = (bytes.iter().max().unwrap(), bytes.iter().min().unwrap());
        assert!(max - min <= cfg.emb_dim * 4, "row split should balance bytes: {bytes:?}");
    }

    #[test]
    fn auto_placement_replans_from_measured_skew() {
        let cfg = tiny_cfg();
        let svc = ShardedEmbeddingService::new(
            &cfg,
            5,
            opts_placed(2, 0.0, PlacementMode::Auto, 0.4),
        )
        .unwrap();
        let single = NativeModel::new(&cfg, 5);
        let (dense, ids, mut lwts) = tiny_inputs(&cfg, 4);
        // Skew the measured load: zero out most of tables 1 and 2's
        // weights so table 0 dominates the recorded lookups.
        let per_table = 4 * cfg.lookups;
        for w in lwts[per_table..].iter_mut().skip(2) {
            *w = 0.0;
        }
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        for i in 0..(AUTO_REPLAN_AFTER_BATCHES + 3) {
            let got = svc.run_rmc(&dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "batch {i} diverged (replan must not change numerics)");
        }
        let s = svc.stats();
        assert_eq!(s.replans, 1, "auto mode must replan once after warmup");
        assert_eq!(s.placement, PlacementMode::Auto);
        assert!(
            s.table_lookups[0] > s.table_lookups[1],
            "skew signal recorded: {:?}",
            s.table_lookups
        );
    }

    #[test]
    fn stats_accumulate_per_stage() {
        let cfg = tiny_cfg();
        let svc = ShardedEmbeddingService::new(&cfg, 3, opts(2, 0.0)).unwrap();
        let (dense, ids, lwts) = tiny_inputs(&cfg, 2);
        svc.run_rmc(&dense, &ids, &lwts).unwrap();
        let s = svc.stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.shards, 2);
        assert_eq!(s.cache_capacity_rows, 0);
        assert_eq!(s.placement, PlacementMode::Whole);
        assert!(s.gather_ns > 0.0 && s.leader_mlp_ns > 0.0);
        assert_eq!(s.cache_hits + s.cache_misses, 0, "no cache traffic when disabled");
        assert_eq!(s.shard_lookups.len(), 2);
        assert_eq!(s.shard_bytes.len(), 2);
        assert!(s.lookup_imbalance() >= 1.0);
        svc.reset_stats();
        assert_eq!(svc.stats().batches, 0);
    }

    #[test]
    fn rejects_bad_options_and_inputs() {
        let cfg = tiny_cfg();
        assert!(
            ShardedEmbeddingService::new(&cfg, 0, opts(0, 0.0)).is_err(),
            "zero shards"
        );
        assert!(
            ShardedEmbeddingService::new(&cfg, 0, opts(2, 1.5)).is_err(),
            "cache fraction > 1"
        );
        assert!(
            ShardedEmbeddingService::new(
                &cfg,
                0,
                ExecOptions { engine: EngineKind::Reference, shards: 2, ..Default::default() }
            )
            .is_err(),
            "reference engine"
        );
        assert!(
            ShardedEmbeddingService::new(
                &cfg,
                0,
                ExecOptions { shards: 2, replicate_hot: 0.1, ..Default::default() }
            )
            .is_err(),
            "replication requires rows/auto placement"
        );
        // A structurally invalid custom plan is rejected up front.
        let bad = Placement {
            shards: 2,
            tables: vec![
                TablePlacement::Replicated(vec![0]),
                TablePlacement::Replicated(vec![0]),
                TablePlacement::Split(vec![RowSegment { shard: 1, rows: (0, 10) }]),
            ],
        };
        assert!(
            ShardedEmbeddingService::with_plan(&cfg, 0, opts(2, 0.0), bad).is_err(),
            "split must cover all rows"
        );
        let svc = ShardedEmbeddingService::new(&cfg, 0, opts(2, 0.0)).unwrap();
        let (dense, mut ids, lwts) = tiny_inputs(&cfg, 2);
        assert!(svc.run_rmc(&dense[..3], &ids, &lwts).is_err(), "ragged dense");
        ids[0] = cfg.pjrt_rows as i32 + 1;
        assert!(svc.run_rmc(&dense, &ids, &lwts).is_err(), "oob id caught on the leader");
        assert!(ShardedEmbeddingService::from_name("nope", 0, opts(2, 0.0)).is_err());
    }

    #[test]
    fn killed_replica_fails_over_bitwise() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 17);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        // Every table fully replicated: shard 1's death must degrade
        // capacity, never availability or numerics.
        let plan = Placement {
            shards: 2,
            tables: (0..cfg.num_tables)
                .map(|_| TablePlacement::Replicated(vec![0, 1]))
                .collect(),
        };
        let svc = ShardedEmbeddingService::with_plan(&cfg, 17, opts(2, 0.0), plan).unwrap();
        assert_eq!(want, svc.run_rmc(&dense, &ids, &lwts).unwrap());
        let routed_before_kill = svc.stats().shard_lookups[1];
        assert!(svc.kill_shard(1));
        assert!(!svc.kill_shard(1), "double kill is a no-op");
        assert!(!svc.kill_shard(9), "out-of-range kill is a no-op");
        assert_eq!(svc.alive_shards(), vec![true, false]);
        for i in 0..2 {
            assert_eq!(
                want,
                svc.run_rmc(&dense, &ids, &lwts).unwrap(),
                "degraded batch {i} diverged from single-node"
            );
        }
        let s = svc.stats();
        assert_eq!(s.shard_deaths, 1);
        assert_eq!(s.shards_alive, 1);
        assert!(s.failover_reads > 0, "failover traffic must be measured: {s:?}");
        // All post-kill reads landed on the survivor.
        assert_eq!(
            s.shard_lookups[1], routed_before_kill,
            "dead shard served reads: {:?}",
            s.shard_lookups
        );
    }

    #[test]
    fn dead_split_owner_is_a_typed_error_and_restart_recovers() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 19);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 3);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        // Table 1's rows live only on shard 1 (declared Split so it is
        // served row-wise): its death has nowhere to fail over to.
        let plan = Placement {
            shards: 2,
            tables: vec![
                TablePlacement::Replicated(vec![0, 1]),
                TablePlacement::Split(vec![RowSegment { shard: 1, rows: (0, 60) }]),
                TablePlacement::Replicated(vec![0]),
            ],
        };
        let svc = ShardedEmbeddingService::with_plan(&cfg, 19, opts(2, 0.0), plan).unwrap();
        assert_eq!(want, svc.run_rmc(&dense, &ids, &lwts).unwrap());
        assert!(svc.kill_shard(1));
        let err = svc.run_rmc(&dense, &ids, &lwts).unwrap_err();
        let su = err
            .downcast_ref::<ShardUnavailable>()
            .unwrap_or_else(|| panic!("untyped shard-loss error: {err:#}"));
        assert_eq!((su.shard, su.table), (1, 1));
        // Restart re-materializes the chunks from the parameter seed
        // and rejoins the topology; service resumes bitwise-identical.
        assert!(svc.restart_shard(1).unwrap());
        assert!(!svc.restart_shard(1).unwrap(), "restart of a live shard is a no-op");
        assert!(!svc.restart_shard(9).unwrap(), "out-of-range restart is a no-op");
        assert_eq!(
            want,
            svc.run_rmc(&dense, &ids, &lwts).unwrap(),
            "post-restart output diverged from single-node"
        );
        let s = svc.stats();
        assert_eq!((s.shard_deaths, s.shard_restarts), (1, 1));
        assert_eq!(s.shards_alive, 2);
    }

    #[test]
    fn cache_mode_failover_stays_bitwise() {
        let cfg = tiny_cfg();
        let single = NativeModel::new(&cfg, 23);
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
        let plan = Placement {
            shards: 2,
            tables: (0..cfg.num_tables)
                .map(|_| TablePlacement::Replicated(vec![0, 1]))
                .collect(),
        };
        let svc =
            ShardedEmbeddingService::with_plan(&cfg, 23, opts(2, 0.5), plan).unwrap();
        assert_eq!(want, svc.run_rmc(&dense, &ids, &lwts).unwrap());
        assert!(svc.kill_shard(0));
        assert_eq!(
            want,
            svc.run_rmc(&dense, &ids, &lwts).unwrap(),
            "cache-mode failover diverged from single-node"
        );
        let s = svc.stats();
        assert!(s.failover_reads > 0, "row-path failover must be measured: {s:?}");
    }

    #[test]
    fn quantized_sharded_matches_single_node_bitwise_and_shrinks_bytes() {
        let cfg = tiny_cfg();
        let (dense, ids, lwts) = tiny_inputs(&cfg, 4);
        for dtype in [TableDtype::F16, TableDtype::Int8] {
            let single = NativeModel::with_dtype(&cfg, 7, dtype);
            let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
            for (shards, cache_rows) in [(2, 0.0), (3, 0.5)] {
                let o = ExecOptions { shards, cache_rows, dtype, ..Default::default() };
                let svc = ShardedEmbeddingService::new(&cfg, 7, o).unwrap();
                assert_eq!(svc.dtype(), dtype);
                for i in 0..2 {
                    assert_eq!(
                        want,
                        svc.run_rmc(&dense, &ids, &lwts).unwrap(),
                        "{} shards={shards} cache={cache_rows} batch {i} diverged",
                        dtype.name()
                    );
                }
                // The capacity split reflects the encoded row size, not
                // a fixed 4 bytes/element.
                let table_bytes = cfg.pjrt_rows * dtype.row_bytes(cfg.emb_dim);
                assert_eq!(
                    svc.shard_bytes().iter().sum::<usize>(),
                    cfg.num_tables * table_bytes,
                    "{}: shard bytes must be dtype-sized",
                    dtype.name()
                );
                assert_eq!(svc.stats().dtype, dtype.name());
            }
        }
    }
}
