//! Runtime-detected AVX2 variants of the optimized engine's two hot
//! kernels: the packed-panel FC GEMM (`fc_packed_rows_avx2`) and the
//! SLS accumulate/dequantize (`sls_axpy_bytes_avx2`).
//!
//! Bitwise contract: every vector kernel performs the *same unfused*
//! `mul` + `add` per element in the *same order* as its scalar twin in
//! `runtime::native` — `_mm256_mul_ps` + `_mm256_add_ps`, never an FMA
//! fusion — and scalar tails reuse the identical per-element arithmetic.
//! Lanes of one ymm register hold *different output elements*, so
//! vectorizing never reassociates any single element's reduction. The
//! result: SIMD on/off can never change served numerics, for any dtype,
//! at any thread count. This is property-tested to 0 ULP in
//! `tests/prop_invariants.rs` and unit-tested per kernel below.
//!
//! Detection policy: one capability bit — `avx2 && fma && f16c` — via
//! `is_x86_feature_detected!`, cached in an atomic. FMA is probed (it
//! travels with AVX2 on every production part and keeps the policy one
//! predictable bit) even though the kernels deliberately never fuse;
//! F16C is required for `_mm256_cvtph_ps` on fp16 rows. Set
//! `RECSYS_NO_SIMD=1` (or pass `--no-simd` to benches/tests via
//! `set_simd_enabled`) to force the portable scalar path — the two are
//! bit-identical, so toggling is always safe.

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "x86_64")]
use super::native::{fc_store_panel, relu_rows, PackedLayer, MR, NR};
use super::native::{TableDtype, INT8_HEADER};

/// Tri-state SIMD switch: 0 = uninitialized, 1 = off, 2 = on.
static SIMD_STATE: AtomicU8 = AtomicU8::new(0);

/// True when the host CPU supports the vector kernels (AVX2 + FMA +
/// F16C on x86_64; always false elsewhere).
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Whether the vector kernels are currently selected. Lazily
/// initialized from CPU detection and the `RECSYS_NO_SIMD` environment
/// variable (set to anything but `0`/empty to force the scalar path).
#[inline]
pub fn simd_enabled() -> bool {
    match SIMD_STATE.load(Ordering::Relaxed) {
        0 => {
            let disabled_by_env = std::env::var("RECSYS_NO_SIMD")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
            let on = simd_available() && !disabled_by_env;
            SIMD_STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
        s => s == 2,
    }
}

/// Force the SIMD path on or off for this process (benches A/B the two
/// variants in-process; tests pin the scalar oracle). Returns the
/// previous state. Requesting `on` without hardware support is a no-op
/// that returns the unchanged state.
pub fn set_simd_enabled(on: bool) -> bool {
    let prev = simd_enabled();
    if !on {
        SIMD_STATE.store(1, Ordering::Relaxed);
    } else if simd_available() {
        SIMD_STATE.store(2, Ordering::Relaxed);
    }
    prev
}

/// AVX2 SLS accumulate: `acc += w * dequant(row)`, 8 output elements
/// per iteration, scalar tail for `len % 8`. Same unfused per-element
/// arithmetic and order as `native::sls_axpy_bytes_scalar`.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 + F16C (`simd_available`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "f16c")]
pub(crate) unsafe fn sls_axpy_bytes_avx2(
    acc: &mut [f32],
    w: f32,
    row: &[u8],
    dtype: TableDtype,
) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let main = n - n % 8;
    let wv = _mm256_set1_ps(w);
    let a = acc.as_mut_ptr();
    match dtype {
        TableDtype::F32 => {
            debug_assert!(row.len() >= n * 4);
            // x86 is little-endian: loading encoded bytes directly is
            // exactly from_le_bytes per element.
            let p = row.as_ptr();
            let mut i = 0;
            while i < main {
                let r = _mm256_loadu_ps(p.add(i * 4) as *const f32);
                let cur = _mm256_loadu_ps(a.add(i));
                _mm256_storeu_ps(a.add(i), _mm256_add_ps(cur, _mm256_mul_ps(wv, r)));
                i += 8;
            }
            for j in main..n {
                let c = std::slice::from_raw_parts(p.add(j * 4), 4);
                *a.add(j) += w * f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        TableDtype::F16 => {
            debug_assert!(row.len() >= n * 2);
            let p = row.as_ptr();
            let mut i = 0;
            while i < main {
                let h = _mm_loadu_si128(p.add(i * 2) as *const __m128i);
                let r = _mm256_cvtph_ps(h);
                let cur = _mm256_loadu_ps(a.add(i));
                _mm256_storeu_ps(a.add(i), _mm256_add_ps(cur, _mm256_mul_ps(wv, r)));
                i += 8;
            }
            for j in main..n {
                let c = std::slice::from_raw_parts(p.add(j * 2), 2);
                let v = super::native::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
                *a.add(j) += w * v;
            }
        }
        TableDtype::Int8 => {
            debug_assert!(row.len() >= INT8_HEADER + n);
            let scale = f32::from_le_bytes(row[0..4].try_into().unwrap());
            let bias = f32::from_le_bytes(row[4..8].try_into().unwrap());
            let sv = _mm256_set1_ps(scale);
            let bv = _mm256_set1_ps(bias);
            let q = row.as_ptr().add(INT8_HEADER);
            let mut i = 0;
            while i < main {
                // 8 bytes -> 8 u32 lanes -> 8 f32 lanes, then the same
                // q * scale + bias the scalar path computes.
                let b8 = _mm_loadl_epi64(q.add(i) as *const __m128i);
                let qi = _mm256_cvtepu8_epi32(b8);
                let qf = _mm256_cvtepi32_ps(qi);
                let v = _mm256_add_ps(_mm256_mul_ps(qf, sv), bv);
                let cur = _mm256_loadu_ps(a.add(i));
                _mm256_storeu_ps(a.add(i), _mm256_add_ps(cur, _mm256_mul_ps(wv, v)));
                i += 8;
            }
            for j in main..n {
                let v = *q.add(j) as f32 * scale + bias;
                *a.add(j) += w * v;
            }
        }
    }
}

/// AVX2 packed-panel GEMM: the 4x16 micro-kernel with the MR*NR
/// accumulator block held in 8 ymm registers (4 rows x 2 halves of the
/// NR=16 panel). Broadcast-multiply-add per k, unfused, ascending k —
/// the identical reduction `native::fc_packed_rows_scalar` performs,
/// so outputs are bit-equal. Row remainders (`rows % MR`) and the
/// bias/ReLU epilogue reuse the scalar code paths outright.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 (`simd_available`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn fc_packed_rows_avx2(
    p: &PackedLayer,
    x: &[f32],
    dst: &mut [f32],
    rows: usize,
) {
    use std::arch::x86_64::*;
    let kdim = p.in_dim;
    let ndim = p.out_dim;
    debug_assert_eq!(x.len(), rows * kdim);
    debug_assert_eq!(dst.len(), rows * ndim);
    debug_assert_eq!(NR, 16);
    let panels = p.panels();
    let mut r = 0;
    while r < rows {
        let mr = MR.min(rows - r);
        for pi in 0..panels {
            let n0 = pi * NR;
            let nc = NR.min(ndim - n0);
            let panel = &p.w[pi * kdim * NR..(pi + 1) * kdim * NR];
            let mut acc = [[0.0f32; NR]; MR];
            if mr == MR {
                let x0 = &x[r * kdim..(r + 1) * kdim];
                let x1 = &x[(r + 1) * kdim..(r + 2) * kdim];
                let x2 = &x[(r + 2) * kdim..(r + 3) * kdim];
                let x3 = &x[(r + 3) * kdim..(r + 4) * kdim];
                let mut a0l = _mm256_setzero_ps();
                let mut a0h = _mm256_setzero_ps();
                let mut a1l = _mm256_setzero_ps();
                let mut a1h = _mm256_setzero_ps();
                let mut a2l = _mm256_setzero_ps();
                let mut a2h = _mm256_setzero_ps();
                let mut a3l = _mm256_setzero_ps();
                let mut a3h = _mm256_setzero_ps();
                let wp = panel.as_ptr();
                for k in 0..kdim {
                    let wl = _mm256_loadu_ps(wp.add(k * NR));
                    let wh = _mm256_loadu_ps(wp.add(k * NR + 8));
                    let v0 = _mm256_set1_ps(x0[k]);
                    let v1 = _mm256_set1_ps(x1[k]);
                    let v2 = _mm256_set1_ps(x2[k]);
                    let v3 = _mm256_set1_ps(x3[k]);
                    a0l = _mm256_add_ps(a0l, _mm256_mul_ps(v0, wl));
                    a0h = _mm256_add_ps(a0h, _mm256_mul_ps(v0, wh));
                    a1l = _mm256_add_ps(a1l, _mm256_mul_ps(v1, wl));
                    a1h = _mm256_add_ps(a1h, _mm256_mul_ps(v1, wh));
                    a2l = _mm256_add_ps(a2l, _mm256_mul_ps(v2, wl));
                    a2h = _mm256_add_ps(a2h, _mm256_mul_ps(v2, wh));
                    a3l = _mm256_add_ps(a3l, _mm256_mul_ps(v3, wl));
                    a3h = _mm256_add_ps(a3h, _mm256_mul_ps(v3, wh));
                }
                _mm256_storeu_ps(acc[0].as_mut_ptr(), a0l);
                _mm256_storeu_ps(acc[0].as_mut_ptr().add(8), a0h);
                _mm256_storeu_ps(acc[1].as_mut_ptr(), a1l);
                _mm256_storeu_ps(acc[1].as_mut_ptr().add(8), a1h);
                _mm256_storeu_ps(acc[2].as_mut_ptr(), a2l);
                _mm256_storeu_ps(acc[2].as_mut_ptr().add(8), a2h);
                _mm256_storeu_ps(acc[3].as_mut_ptr(), a3l);
                _mm256_storeu_ps(acc[3].as_mut_ptr().add(8), a3h);
            } else {
                // Row remainder: the scalar remainder loop verbatim
                // (same per-element k order; not worth vectorizing).
                for (m, a) in acc.iter_mut().enumerate().take(mr) {
                    let xrow = &x[(r + m) * kdim..(r + m + 1) * kdim];
                    for (k, &xv) in xrow.iter().enumerate() {
                        let w = &panel[k * NR..k * NR + NR];
                        for j in 0..NR {
                            a[j] += xv * w[j];
                        }
                    }
                }
            }
            fc_store_panel(p, dst, &acc, r, mr, n0, nc);
        }
        if p.relu {
            relu_rows(dst, ndim, r, mr);
        }
        r += mr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_arch = "x86_64")]
    use super::super::native::{
        fc_packed_rows_scalar, sls_axpy_bytes_scalar, DenseLayer, TableRows,
    };

    #[test]
    fn detection_is_consistent() {
        // simd_enabled can only be true on hardware that supports it.
        if simd_enabled() {
            assert!(simd_available());
        }
        // The override round-trips and never enables without support.
        let prev = set_simd_enabled(false);
        assert!(!simd_enabled());
        set_simd_enabled(true);
        assert_eq!(simd_enabled(), simd_available());
        set_simd_enabled(prev);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_sls_axpy_bitwise_equals_scalar() {
        if !simd_available() {
            println!("skipping avx2_sls_axpy_bitwise_equals_scalar: no AVX2/FMA/F16C");
            return;
        }
        let mut rng = crate::util::Rng::seed_from_u64(42);
        for emb in [1usize, 7, 8, 16, 27, 64, 65] {
            let row: Vec<f32> = (0..emb).map(|_| rng.normal() as f32).collect();
            for dtype in [TableDtype::F32, TableDtype::F16, TableDtype::Int8] {
                let t = TableRows::encode(dtype, emb, &row);
                let init: Vec<f32> = (0..emb).map(|_| rng.normal() as f32).collect();
                let w = rng.normal() as f32;
                let mut a = init.clone();
                let mut b = init;
                sls_axpy_bytes_scalar(&mut a, w, t.row(0), dtype);
                unsafe { sls_axpy_bytes_avx2(&mut b, w, t.row(0), dtype) };
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{dtype:?} emb={emb}: {x} vs {y}");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_gemm_bitwise_equals_scalar() {
        if !simd_available() {
            println!("skipping avx2_gemm_bitwise_equals_scalar: no AVX2/FMA/F16C");
            return;
        }
        let mut rng = crate::util::Rng::seed_from_u64(7);
        for (kdim, ndim, relu) in [(3usize, 5usize, false), (8, 16, true), (17, 33, true)] {
            let layer = DenseLayer {
                in_dim: kdim,
                out_dim: ndim,
                w: (0..kdim * ndim).map(|_| rng.normal() as f32).collect(),
                b: (0..ndim).map(|_| rng.normal() as f32).collect(),
                relu,
            };
            let p = PackedLayer::pack(&layer);
            for rows in [1usize, 3, 4, 5, 9] {
                let x: Vec<f32> = (0..rows * kdim).map(|_| rng.normal() as f32).collect();
                let mut a = vec![0.0f32; rows * ndim];
                let mut b = vec![0.0f32; rows * ndim];
                fc_packed_rows_scalar(&p, &x, &mut a, rows);
                unsafe { fc_packed_rows_avx2(&p, &x, &mut b, rows) };
                for (u, v) in a.iter().zip(&b) {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "k={kdim} n={ndim} rows={rows}: {u} vs {v}"
                    );
                }
            }
        }
    }
}
