//! Set-associative cache model with true-LRU replacement.
//!
//! Lines are identified by 64B-line-granular addresses (caller shifts).
//! The model supports probe / insert / invalidate separately so the
//! hierarchy can implement both inclusive (back-invalidating) and
//! exclusive (victim) L2/L3 policies on top of it.

/// Statistics kept per cache instance.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub invalidations: u64,
}

const INVALID: u64 = u64::MAX;

#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    /// tags[set * ways + way] = line address (INVALID if empty).
    tags: Vec<u64>,
    /// LRU timestamps, parallel to `tags`.
    lru: Vec<u64>,
    tick: u64,
    pub stats: CacheStats,
}

impl Cache {
    /// Build from a capacity in bytes, associativity, and 64B lines.
    pub fn new(capacity_bytes: u64, ways: usize) -> Self {
        let lines = (capacity_bytes / 64).max(1) as usize;
        let ways = ways.min(lines).max(1);
        let sets = (lines / ways).max(1);
        Cache {
            sets,
            ways,
            tags: vec![INVALID; sets * ways],
            lru: vec![0; sets * ways],
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        (self.sets * self.ways * 64) as u64
    }

    fn set_of(&self, line: u64) -> usize {
        // Multiplicative hash to spread instance-tagged address spaces
        // across sets (real caches hash physical addresses too).
        let h = line.wrapping_mul(0x9E3779B97F4A7C15) >> 16;
        (h % self.sets as u64) as usize
    }

    /// Probe for a line; updates LRU and hit/miss stats.
    pub fn probe(&mut self, line: u64) -> bool {
        debug_assert_ne!(line, INVALID);
        let set = self.set_of(line);
        let base = set * self.ways;
        self.tick += 1;
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                self.lru[base + w] = self.tick;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        false
    }

    /// Check presence without touching stats or LRU.
    pub fn contains(&self, line: u64) -> bool {
        let set = self.set_of(line);
        let base = set * self.ways;
        (0..self.ways).any(|w| self.tags[base + w] == line)
    }

    /// Insert a line, returning the evicted victim (if any). Inserting a
    /// line that is already present refreshes its LRU and evicts nothing.
    pub fn insert(&mut self, line: u64) -> Option<u64> {
        debug_assert_ne!(line, INVALID);
        let set = self.set_of(line);
        let base = set * self.ways;
        self.tick += 1;
        // Already present -> refresh.
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                self.lru[base + w] = self.tick;
                return None;
            }
        }
        // Empty way?
        for w in 0..self.ways {
            if self.tags[base + w] == INVALID {
                self.tags[base + w] = line;
                self.lru[base + w] = self.tick;
                return None;
            }
        }
        // Evict LRU.
        let mut victim_w = 0;
        for w in 1..self.ways {
            if self.lru[base + w] < self.lru[base + victim_w] {
                victim_w = w;
            }
        }
        let victim = self.tags[base + victim_w];
        self.tags[base + victim_w] = line;
        self.lru[base + victim_w] = self.tick;
        self.stats.evictions += 1;
        Some(victim)
    }

    /// Remove a line if present (back-invalidation / exclusive-move).
    pub fn invalidate(&mut self, line: u64) -> bool {
        let set = self.set_of(line);
        let base = set * self.ways;
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                self.tags[base + w] = INVALID;
                self.stats.invalidations += 1;
                return true;
            }
        }
        false
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Number of valid lines (for occupancy assertions in tests).
    pub fn occupancy(&self) -> usize {
        self.tags.iter().filter(|&&t| t != INVALID).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert() {
        let mut c = Cache::new(64 * 16, 4);
        assert!(!c.probe(42));
        c.insert(42);
        assert!(c.probe(42));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways.
        let mut c = Cache::new(64 * 2, 2);
        assert_eq!(c.capacity_bytes(), 128);
        c.insert(1);
        c.insert(2);
        c.probe(1); // 2 is now LRU
        let evicted = c.insert(3);
        assert_eq!(evicted, Some(2));
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = Cache::new(64 * 2, 2);
        c.insert(1);
        c.insert(2);
        assert_eq!(c.insert(1), None); // refresh
        assert_eq!(c.insert(3), Some(2)); // 2 was LRU after refresh
    }

    #[test]
    fn invalidate_removes() {
        let mut c = Cache::new(64 * 8, 2);
        c.insert(5);
        assert!(c.invalidate(5));
        assert!(!c.contains(5));
        assert!(!c.invalidate(5));
        assert_eq!(c.stats.invalidations, 1);
    }

    #[test]
    fn occupancy_bounded_by_capacity() {
        let mut c = Cache::new(64 * 32, 4);
        for i in 0..1000 {
            c.insert(i);
        }
        assert_eq!(c.occupancy(), 32);
    }

    #[test]
    fn working_set_fits_gets_full_hits() {
        let mut c = Cache::new(64 * 64, 8);
        let lines: Vec<u64> = (0..48).collect();
        for &l in &lines {
            c.probe(l);
            c.insert(l);
        }
        c.reset_stats();
        for _ in 0..10 {
            for &l in &lines {
                assert!(c.probe(l));
            }
        }
        assert_eq!(c.stats.misses, 0);
    }
}
