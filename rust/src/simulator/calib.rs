//! Calibration constants for the timing model.
//!
//! Every constant here is either (a) a documented micro-architectural
//! value, or (b) a free parameter tuned once so the simulator reproduces
//! the paper's *reported* numbers (Fig 7 unit-batch latencies, Fig 8
//! speedup ratios, §V SIMD utilization, §VI MPKI deltas). The tuning
//! procedure and residuals are recorded in EXPERIMENTS.md §Calibration.

/// Per-operator framework dispatch overhead, ns. Caffe2 operator launch +
/// MKL call overhead; dominant for sub-10µs ops at unit batch.
pub const DISPATCH_OVERHEAD_NS: f64 = 1400.0;

/// Memory-level parallelism of SLS gathers. The paper measures ~1GB/s
/// DRAM utilization for SLS (≈ one 64B line per 64ns) — latency-bound
/// with little overlap, hence a factor close to 1.
pub const SLS_MLP_FACTOR: f64 = 1.35;

/// Extra per-line cost of streaming the second+ cache line of one
/// embedding row (adjacent-line prefetch makes it bandwidth-ish), ns.
pub const ADJACENT_LINE_NS: f64 = 8.0;

/// AVX-2 (Haswell/Broadwell) GEMM SIMD-efficiency curve:
/// eff(M) = EFF0 + (EFF_MAX - EFF0) * M / (M + M_HALF).
/// AVX-2 reaches high utilization at small batch (8-wide vectors are
/// easy to fill from a GEMV).
pub const AVX2_EFF0: f64 = 0.32;
pub const AVX2_EFF_MAX: f64 = 0.95;
pub const AVX2_M_HALF: f64 = 8.0;

/// AVX-512 (Skylake) curve: unit-batch GEMV barely uses 512-bit lanes;
/// the paper's §V perf-counter readings (74% of theoretical packed
/// throughput at batch 4, 91% at 16, saturating ≥128) anchor M_HALF.
pub const AVX512_EFF0: f64 = 0.10;
pub const AVX512_EFF_MAX: f64 = 0.92;
pub const AVX512_M_HALF: f64 = 36.0;

/// Single-core sustained DRAM streaming bandwidth cap, GB/s (a core
/// cannot saturate the socket's channels alone).
pub const PER_CORE_DRAM_BW_GBS: f64 = 14.0;

/// Element-wise ops (ReLU, concat, sigmoid) stream through L1/L2 at this
/// effective bandwidth, GB/s.
pub const ELEMENTWISE_BW_GBS: f64 = 24.0;

/// DRAM queueing: effective access latency grows by this fraction per
/// additional active memory-intensive job sharing the socket.
pub const DRAM_CONTENTION_ALPHA: f64 = 0.12;

/// Scalar (non-SIMD) per-lookup overhead of the SLS inner loop —
/// index arithmetic, bounds checks, loop control — in core cycles.
/// Scales with core frequency (part of why Broadwell beats the
/// lower-clocked Skylake at low co-location, Fig 10).
pub const SLS_SCALAR_CYCLES_PER_LOOKUP: f64 = 12.0;

/// Duty cycle of co-located background jobs (fraction of time a
/// co-runner is actively issuing memory traffic). Drives the stochastic
/// contention states behind Fig 11's multi-modality.
pub const COLOCATION_DUTY: f64 = 0.72;

/// Multiplicative log-normal jitter (sigma) on per-op latency in the
/// production-environment model (scheduler noise, interrupts).
pub const PRODUCTION_JITTER_SIGMA: f64 = 0.035;

/// Hyperthreading penalties (paper §VI): two threads share a physical
/// core's SIMD ports; FC suffers 1.6x, SLS 1.3x.
pub const HT_FC_PENALTY: f64 = 1.6;
pub const HT_SLS_PENALTY: f64 = 1.3;

/// L3 traffic (MB) each active co-runner streams between two
/// invocations of a given operator — the eviction pressure that
/// determines whether an FC's weights survive in the shared LLC
/// (Fig 11's latency modes).
pub const CO_RUNNER_TRAFFIC_MB: f64 = 8.0;

/// Fraction of an FC's weight-streaming time NOT hidden under compute
/// (imperfect prefetch/compute overlap). 0 = perfect roofline max();
/// 1 = fully serialized. Drives RMC3's co-location degradation (Fig 9).
pub const FC_MEM_EXPOSED_FRACTION: f64 = 0.7;

/// Fraction of L2 usable by one op's working set (the rest is code,
/// stack, activation churn).
pub const L2_USABLE_FRACTION: f64 = 0.80;

/// Fraction of the (share of) L3 usable for FC weights when SLS streams
/// co-reside (pollution guard).
pub const L3_USABLE_FRACTION: f64 = 0.70;

/// §V packed-SIMD instruction-retirement model: measured utilization of
/// theoretical packed-op scaling at batch 4 and 16 (74% / 91%), used by
/// `CoreModel::packed_simd_ratio`.
pub const PACKED_RATIO_HALF_BATCH: f64 = 1.45;

#[cfg(test)]
mod tests {
    /// The efficiency curves must preserve the paper's architectural
    /// ordering: AVX-2 beats AVX-512 in *utilization* at low batch, and
    /// AVX-512's absolute throughput wins at high batch.
    #[test]
    fn efficiency_curve_crossover() {
        let eff =
            |e0: f64, emax: f64, mh: f64, m: f64| e0 + (emax - e0) * m / (m + mh);
        // Sustained AVX clocks (Table II + licensing downclock).
        let bdw = |m: f64| {
            2.3 * 32.0 * eff(super::AVX2_EFF0, super::AVX2_EFF_MAX, super::AVX2_M_HALF, m)
        };
        let skl = |m: f64| {
            1.7 * 64.0
                * eff(super::AVX512_EFF0, super::AVX512_EFF_MAX, super::AVX512_M_HALF, m)
        };
        assert!(bdw(1.0) > skl(1.0), "Broadwell wins unit batch");
        assert!(bdw(16.0) > skl(16.0), "Broadwell wins batch 16");
        assert!(skl(128.0) > bdw(128.0), "Skylake wins batch 128");
        assert!(skl(256.0) > bdw(256.0), "Skylake wins batch 256");
    }
}
