//! Co-location simulation (paper §VI): N inference jobs on one machine
//! sharing the L3 and DRAM. Jobs interleave at inference granularity on
//! the shared hierarchy; at any instant a stochastic subset of
//! co-runners is actively issuing memory traffic (duty cycle), which is
//! what quantizes Broadwell's latency into the discrete modes of
//! Fig 11a and blows up its p99 under high co-location.

use crate::config::{RmcConfig, ServerSpec};
use crate::metrics::{CacheCounters, LatencyHistogram};
use crate::model::{ModelGraph, Op, OpCategory};
use crate::util::Rng;
use crate::workload::SparseIdGen;

use super::calib;
use super::machine::MachineSim;

/// Aggregated outcome of a co-location run.
#[derive(Debug, Clone)]
pub struct ColocationResult {
    pub n_jobs: usize,
    pub batch: usize,
    /// Per-inference latency distribution (ms), pooled across jobs.
    pub latency_ms: LatencyHistogram,
    /// Mean time per category per inference (ns).
    pub mean_cat_ns: std::collections::HashMap<OpCategory, f64>,
    /// Mean per-model cache counters per inference.
    pub counters: CacheCounters,
    pub inferences: usize,
    pub instructions: u64,
}

impl ColocationResult {
    pub fn mean_ms(&self) -> f64 {
        self.latency_ms.clone().mean()
    }

    pub fn llc_mpki(&self) -> f64 {
        self.counters.llc_misses() as f64 / (self.instructions as f64 / 1000.0).max(1e-9)
    }

    pub fn l2_mpki(&self) -> f64 {
        self.counters.l2_misses() as f64 / (self.instructions as f64 / 1000.0).max(1e-9)
    }

    /// Aggregate machine throughput in inferences/sec, assuming all
    /// `n_jobs` run closed-loop at the measured mean latency.
    pub fn throughput_ips(&self) -> f64 {
        self.n_jobs as f64 / (self.mean_ms() / 1e3)
    }
}

/// Homogeneous co-location of `n_jobs` copies of one model.
pub struct ColocationSim {
    pub machine: MachineSim,
    graph: ModelGraph,
    batch: usize,
    n_jobs: usize,
    idgens: Vec<SparseIdGen>,
    activity_rng: Rng,
}

impl ColocationSim {
    pub fn new(spec: ServerSpec, cfg: &RmcConfig, batch: usize, n_jobs: usize, seed: u64) -> Self {
        assert!(n_jobs >= 1);
        let machine = MachineSim::new(spec, n_jobs).with_production_jitter(seed);
        let graph = ModelGraph::from_rmc(cfg);
        let idgens = (0..n_jobs)
            .map(|i| SparseIdGen::production_like(cfg.rows, seed ^ (i as u64 * 0x9E37)))
            .collect();
        ColocationSim {
            machine,
            graph,
            batch,
            n_jobs,
            idgens,
            activity_rng: Rng::seed_from_u64(seed ^ 0xAC71),
        }
    }

    /// Sample how many jobs are actively issuing memory traffic right
    /// now: this job plus Binomial(n-1, duty) co-runners.
    fn sample_active(&mut self) -> usize {
        if self.n_jobs == 1 {
            return 1;
        }
        1 + self
            .activity_rng
            .binomial((self.n_jobs - 1) as u64, calib::COLOCATION_DUTY) as usize
    }

    /// Interleave `rounds` inferences per job after `warm` warm-up
    /// rounds; returns pooled statistics.
    pub fn run(&mut self, warm: usize, rounds: usize) -> ColocationResult {
        for _ in 0..warm {
            for j in 0..self.n_jobs {
                let active = self.sample_active();
                self.machine
                    .run_inference(j, &self.graph, self.batch, &mut self.idgens[j], active);
            }
        }
        let mut latency_ms = LatencyHistogram::new();
        let mut mean_cat_ns: std::collections::HashMap<OpCategory, f64> = Default::default();
        let mut counters = CacheCounters::default();
        let mut instructions = 0u64;
        let mut inferences = 0usize;
        for _ in 0..rounds {
            for j in 0..self.n_jobs {
                let active = self.sample_active();
                let b = self.machine.run_inference(
                    j,
                    &self.graph,
                    self.batch,
                    &mut self.idgens[j],
                    active,
                );
                latency_ms.record(b.ms());
                for (c, ns) in &b.by_cat {
                    *mean_cat_ns.entry(*c).or_default() += ns;
                }
                counters.add(&b.counters);
                instructions += b.instructions;
                inferences += 1;
            }
        }
        for v in mean_cat_ns.values_mut() {
            *v /= inferences as f64;
        }
        // Normalize counters/instructions to per-inference means.
        let n = inferences as u64;
        counters = CacheCounters {
            l1_hits: counters.l1_hits / n,
            l2_hits: counters.l2_hits / n,
            l3_hits: counters.l3_hits / n,
            dram_accesses: counters.dram_accesses / n,
            l2_back_invalidations: counters.l2_back_invalidations / n,
        };
        ColocationResult {
            n_jobs: self.n_jobs,
            batch: self.batch,
            latency_ms,
            mean_cat_ns,
            counters,
            inferences,
            instructions: instructions / n,
        }
    }
}

/// Fig 11 harness: distribution of a standalone FC operator co-located
/// with `n_bg` RMC1 jobs in the production environment.
pub fn focal_fc_distribution(
    spec: ServerSpec,
    d_in: usize,
    d_out: usize,
    batch: usize,
    n_bg: usize,
    executions: usize,
    seed: u64,
) -> LatencyHistogram {
    let bg_cfg = crate::config::rmc1_small();
    let bg_graph = ModelGraph::from_rmc(&bg_cfg);
    let mut machine = MachineSim::new(spec, n_bg + 1).with_production_jitter(seed);
    let mut bg_gens: Vec<SparseIdGen> = (0..n_bg)
        .map(|i| SparseIdGen::production_like(bg_cfg.rows, seed ^ (i as u64 * 31)))
        .collect();
    let mut rng = Rng::seed_from_u64(seed ^ 0xF0CA1);
    let op = Op::Fc { d_in, d_out };
    let mut hist = LatencyHistogram::new();
    for _ in 0..executions {
        // A stochastic subset of background jobs runs (pollutes L3).
        let active = if n_bg == 0 {
            1
        } else {
            1 + rng.binomial(n_bg as u64, calib::COLOCATION_DUTY) as usize
        };
        for j in 0..active.saturating_sub(1).min(n_bg) {
            // Background jobs run small-batch RMC1 inferences.
            machine.run_inference(1 + j, &bg_graph, 4, &mut bg_gens[j], active);
        }
        let us = machine.time_op(&op, batch, active) / 1e3;
        hist.record(us);
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn colocation_degrades_latency() {
        // Fig 9: 8 co-located jobs degrade per-model latency.
        let cfg = presets::rmc2_small();
        let solo = ColocationSim::new(ServerSpec::broadwell(), &cfg, 32, 1, 1)
            .run(2, 4)
            .mean_ms();
        let co8 = ColocationSim::new(ServerSpec::broadwell(), &cfg, 32, 8, 1)
            .run(2, 4)
            .mean_ms();
        assert!(co8 > 1.2 * solo, "co8 {co8} vs solo {solo}");
    }

    #[test]
    fn rmc2_degrades_more_than_rmc3() {
        // Fig 9: RMC2 (irregular) suffers more than RMC3 (compute).
        let deg = |cfg: &RmcConfig| {
            let solo = ColocationSim::new(ServerSpec::broadwell(), cfg, 32, 1, 3)
                .run(2, 3)
                .mean_ms();
            let co = ColocationSim::new(ServerSpec::broadwell(), cfg, 32, 8, 3)
                .run(2, 3)
                .mean_ms();
            co / solo
        };
        let d2 = deg(&presets::rmc2_small());
        let d3 = deg(&presets::rmc3_small());
        assert!(d2 > d3, "rmc2 degradation {d2} should exceed rmc3 {d3}");
    }

    #[test]
    fn inclusive_hierarchy_degrades_more() {
        // Takeaway 7: Broadwell (inclusive) suffers more than Skylake
        // (exclusive) under identical co-location.
        let cfg = presets::rmc2_small();
        let rel = |spec: ServerSpec| {
            let solo = ColocationSim::new(spec.clone(), &cfg, 32, 1, 5).run(2, 3).mean_ms();
            let co = ColocationSim::new(spec, &cfg, 32, 12, 5).run(2, 3).mean_ms();
            co / solo
        };
        let bdw = rel(ServerSpec::broadwell());
        let skl = rel(ServerSpec::skylake());
        assert!(bdw > skl, "bdw degradation {bdw} <= skl {skl}");
    }

    #[test]
    fn focal_fc_broadwell_multimodal_skylake_unimodal() {
        // Fig 11a: FC 512x512 (1MB weights) fits Skylake L2, only
        // Broadwell LLC.
        let bdw = focal_fc_distribution(ServerSpec::broadwell(), 512, 512, 1, 20, 120, 9);
        let skl = focal_fc_distribution(ServerSpec::skylake(), 512, 512, 1, 20, 120, 9);
        let spread = |mut h: LatencyHistogram| h.p99() / h.p5();
        assert!(
            spread(bdw.clone()) > spread(skl.clone()),
            "bdw spread {} <= skl spread {}",
            spread(bdw),
            spread(skl)
        );
    }

    #[test]
    fn back_invalidations_only_on_inclusive() {
        let cfg = presets::rmc2_small();
        let bdw = ColocationSim::new(ServerSpec::broadwell(), &cfg, 32, 8, 2).run(1, 3);
        let skl = ColocationSim::new(ServerSpec::skylake(), &cfg, 32, 8, 2).run(1, 3);
        assert!(bdw.counters.l2_back_invalidations > 0);
        assert_eq!(skl.counters.l2_back_invalidations, 0);
    }
}
