//! Single-core compute model: frequency x SIMD width x batch-dependent
//! utilization (paper §V). The efficiency curves are calibrated so that
//! Broadwell's AVX-2 wins small-batch GEMMs on clock + utilization while
//! Skylake's AVX-512 wins once the batch fills 512-bit lanes (>= ~64-128,
//! matching Fig 8's crossovers).

use crate::config::{ServerSpec, SimdIsa};

use super::calib;

#[derive(Debug, Clone)]
pub struct CoreModel {
    pub freq_ghz: f64,
    pub simd: SimdIsa,
}

impl CoreModel {
    pub fn from_spec(spec: &ServerSpec) -> Self {
        CoreModel { freq_ghz: spec.avx_freq_ghz, simd: spec.simd }
    }

    /// GEMM SIMD efficiency in (0, 1]: fraction of peak FLOPs/cycle
    /// achieved at batch (GEMM M-dim) `m`.
    pub fn simd_efficiency(&self, m: usize) -> f64 {
        let (e0, emax, mh) = match self.simd {
            SimdIsa::Avx2 => (calib::AVX2_EFF0, calib::AVX2_EFF_MAX, calib::AVX2_M_HALF),
            SimdIsa::Avx512 => {
                (calib::AVX512_EFF0, calib::AVX512_EFF_MAX, calib::AVX512_M_HALF)
            }
        };
        let m = m as f64;
        e0 + (emax - e0) * m / (m + mh)
    }

    /// Effective single-core GFLOP/s for a batch-`m` GEMM.
    pub fn effective_gflops(&self, m: usize) -> f64 {
        self.freq_ghz * self.simd.peak_flops_per_cycle() * self.simd_efficiency(m)
    }

    /// §V perf-counter model: ratio of packed-SIMD instructions retired
    /// per unit time at batch `m` relative to unit batch. The paper
    /// measures 2.9x at batch 4 (74% of the theoretical 4x) and 14.5x at
    /// batch 16 (91% of 16x) for AVX-512.
    pub fn packed_simd_ratio(&self, m: usize) -> f64 {
        let m = m as f64;
        // ratio(m) = m * util(m), util(m) = m / (m + h): measured packed
        // throughput is util(m) of the theoretical m-fold scaling.
        (m * m / (m + calib::PACKED_RATIO_HALF_BATCH)).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerSpec;

    #[test]
    fn efficiency_monotone_in_batch() {
        let c = CoreModel::from_spec(&ServerSpec::skylake());
        let mut prev = 0.0;
        for m in [1, 4, 16, 64, 256, 1024] {
            let e = c.simd_efficiency(m);
            assert!(e > prev && e <= 1.0);
            prev = e;
        }
    }

    #[test]
    fn broadwell_beats_skylake_small_batch_only() {
        let b = CoreModel::from_spec(&ServerSpec::broadwell());
        let s = CoreModel::from_spec(&ServerSpec::skylake());
        assert!(b.effective_gflops(1) > s.effective_gflops(1));
        assert!(b.effective_gflops(16) > s.effective_gflops(16));
        assert!(s.effective_gflops(128) > b.effective_gflops(128));
    }

    #[test]
    fn packed_ratio_matches_paper_section5() {
        // Paper: batch 4 -> 2.9x (74% of 4x); batch 16 -> 14.5x (91%).
        let s = CoreModel::from_spec(&ServerSpec::skylake());
        let r4 = s.packed_simd_ratio(4);
        let r16 = s.packed_simd_ratio(16);
        assert!((r4 / 4.0 - 0.74).abs() < 0.05, "util(4) = {}", r4 / 4.0);
        assert!((r16 / 16.0 - 0.91).abs() < 0.03, "util(16) = {}", r16 / 16.0);
    }

    #[test]
    fn haswell_and_broadwell_share_isa() {
        let h = CoreModel::from_spec(&ServerSpec::haswell());
        let b = CoreModel::from_spec(&ServerSpec::broadwell());
        assert_eq!(h.simd_efficiency(32), b.simd_efficiency(32));
        // Haswell's base clock is higher but its AVX licensing downclock
        // is harsher -> Broadwell sustains more FLOPs (Takeaway 3).
        assert!(b.effective_gflops(32) > h.effective_gflops(32));
    }
}
