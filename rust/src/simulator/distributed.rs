//! Distributed (sharded) inference — the paper's §VII extension
//! direction ("running recommendation models across many nodes
//! (distributed inference)"): RMC2-class models exceed one node's DRAM
//! comfort zone (≈10 GB of tables), so production shards embedding
//! tables table-wise across nodes; a leader fans requests out, shards
//! compute their SLS partials, and the leader runs the MLPs on the
//! gathered vectors.
//!
//! This module simulates that topology on the modeled Table II servers:
//! per-shard SLS time comes from the same trace-driven machine model,
//! plus a network model (RTT + serialized payload). It answers the
//! design question the paper raises: when does sharding pay?

use crate::config::{RmcConfig, ServerSpec};
use crate::model::{ModelGraph, Op, OpCategory};
use crate::simulator::MachineSim;
use crate::workload::SparseIdGen;

/// Datacenter-network model (same-rack RDMA-ish defaults).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way latency leader <-> shard, ns.
    pub rtt_ns: f64,
    /// Link bandwidth, GB/s.
    pub bw_gbs: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 25GbE-class intra-rack: ~15us RTT, ~3 GB/s effective.
        NetworkModel { rtt_ns: 15_000.0, bw_gbs: 3.0 }
    }
}

/// Result of one sharded-inference simulation.
#[derive(Debug, Clone)]
pub struct ShardedResult {
    pub shards: usize,
    pub batch: usize,
    /// End-to-end latency, ms.
    pub total_ms: f64,
    /// Slowest shard's SLS time, ms.
    pub shard_sls_ms: f64,
    /// Leader-side MLP + glue time, ms.
    pub leader_ms: f64,
    /// Network (fan-out + gather) time, ms.
    pub network_ms: f64,
    /// Aggregate embedding bytes per shard (the memory-capacity win).
    pub shard_emb_bytes: u64,
}

/// Simulate one batch-`batch` inference of `cfg` sharded table-wise over
/// `shards` nodes of `spec`, with the leader on an identical node.
pub fn simulate_sharded(
    cfg: &RmcConfig,
    spec: &ServerSpec,
    net: &NetworkModel,
    shards: usize,
    batch: usize,
    seed: u64,
) -> ShardedResult {
    assert!(shards >= 1);
    let tables_per_shard = cfg.num_tables.div_ceil(shards);

    // --- shard side: SLS over its subset of tables (trace-driven). ----
    let shard_graph = ModelGraph {
        name: format!("{}-shard", cfg.name),
        class: cfg.class,
        ops: (0..tables_per_shard)
            .map(|_| Op::Sls { rows: cfg.rows, emb_dim: cfg.emb_dim, lookups: cfg.lookups })
            .collect(),
    };
    let mut shard_sim = MachineSim::new(spec.clone(), 1);
    let mut idgen = SparseIdGen::production_like(cfg.rows, seed);
    shard_sim.warmup(0, &shard_graph, batch, &mut idgen, 2);
    let shard_b = shard_sim.run_inference(0, &shard_graph, batch, &mut idgen, 1);
    let shard_sls_ns = shard_b.total_ns;

    // --- leader side: bottom+top MLP, concat, sigmoid (no SLS). -------
    let leader_graph = ModelGraph {
        name: format!("{}-leader", cfg.name),
        class: cfg.class,
        ops: ModelGraph::from_rmc(cfg)
            .ops
            .into_iter()
            .filter(|o| o.category() != OpCategory::Sls)
            .collect(),
    };
    let mut leader_sim = MachineSim::new(spec.clone(), 1);
    let mut idgen2 = SparseIdGen::production_like(cfg.rows, seed ^ 1);
    leader_sim.warmup(0, &leader_graph, batch, &mut idgen2, 2);
    let leader_ns = leader_sim.run_inference(0, &leader_graph, batch, &mut idgen2, 1).total_ns;

    // --- network: scatter ids + gather embedding partials. ------------
    let network_ns = if shards == 1 {
        0.0 // co-located: no fan-out
    } else {
        let ids_bytes = (batch * tables_per_shard * cfg.lookups * 8) as f64;
        let emb_bytes = (batch * tables_per_shard * cfg.emb_dim * 4) as f64;
        2.0 * net.rtt_ns + (ids_bytes + emb_bytes) / net.bw_gbs
    };

    ShardedResult {
        shards,
        batch,
        total_ms: (shard_sls_ns + leader_ns + network_ns) / 1e6,
        shard_sls_ms: shard_sls_ns / 1e6,
        leader_ms: leader_ns / 1e6,
        network_ms: network_ns / 1e6,
        shard_emb_bytes: tables_per_shard as u64 * cfg.rows as u64 * cfg.emb_dim as u64 * 4,
    }
}

/// Sweep shard counts; returns one result per count.
pub fn shard_sweep(
    cfg: &RmcConfig,
    spec: &ServerSpec,
    net: &NetworkModel,
    counts: &[usize],
    batch: usize,
) -> Vec<ShardedResult> {
    counts
        .iter()
        .map(|&n| simulate_sharded(cfg, spec, net, n, batch, 17))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, ServerSpec};

    #[test]
    fn sharding_cuts_per_node_memory_linearly() {
        let cfg = presets::rmc2_large();
        let r1 = simulate_sharded(&cfg, &ServerSpec::skylake(), &NetworkModel::default(), 1, 8, 1);
        let r8 = simulate_sharded(&cfg, &ServerSpec::skylake(), &NetworkModel::default(), 8, 8, 1);
        assert!(r8.shard_emb_bytes <= r1.shard_emb_bytes / 7);
        // 10GB-class model becomes ~1.3GB/node at 8 shards.
        assert!(r8.shard_emb_bytes < 2_000_000_000);
    }

    #[test]
    fn sharding_helps_rmc2_latency_at_moderate_counts() {
        // RMC2 is SLS-bound: splitting 32 tables over 4 nodes should beat
        // single-node despite the network hop.
        let cfg = presets::rmc2_large();
        let r = shard_sweep(
            &cfg,
            &ServerSpec::broadwell(),
            &NetworkModel::default(),
            &[1, 4],
            32,
        );
        assert!(
            r[1].total_ms < r[0].total_ms,
            "4 shards {} !< 1 shard {}",
            r[1].total_ms,
            r[0].total_ms
        );
    }

    #[test]
    fn sharding_hurts_compute_bound_rmc3() {
        // RMC3 has 3 tables and a huge MLP: sharding buys nothing and
        // pays the network cost.
        let cfg = presets::rmc3_large();
        let r = shard_sweep(
            &cfg,
            &ServerSpec::broadwell(),
            &NetworkModel::default(),
            &[1, 3],
            8,
        );
        assert!(r[1].total_ms >= r[0].total_ms * 0.95, "{r:?}");
    }

    #[test]
    fn network_time_zero_for_single_node() {
        let cfg = presets::rmc1_small();
        let r = simulate_sharded(&cfg, &ServerSpec::haswell(), &NetworkModel::default(), 1, 4, 3);
        assert_eq!(r.network_ms, 0.0);
        assert!(r.total_ms > 0.0);
    }

    #[test]
    fn diminishing_returns_with_more_shards() {
        // Marginal gain from 8 -> 16 shards is smaller than 1 -> 4.
        let cfg = presets::rmc2_large();
        let r = shard_sweep(
            &cfg,
            &ServerSpec::skylake(),
            &NetworkModel::default(),
            &[1, 4, 8, 16],
            32,
        );
        let g14 = r[0].total_ms - r[1].total_ms;
        let g816 = r[2].total_ms - r[3].total_ms;
        assert!(g14 > g816, "gain 1->4 {g14} should exceed 8->16 {g816}");
    }
}
