//! DRAM timing model: DDR3/DDR4 access latency with co-location queueing
//! and bandwidth sharing (paper Takeaway 3: the Haswell-Broadwell gap is
//! DDR3-1600 vs DDR4-2400; §VI: co-runners share socket bandwidth).

use crate::config::ServerSpec;

use super::calib;

#[derive(Debug, Clone)]
pub struct DramModel {
    /// Idle (unloaded) access latency, ns.
    pub lat_ns: f64,
    /// Total socket bandwidth available to this machine's jobs, GB/s.
    pub bw_gbs: f64,
}

impl DramModel {
    pub fn from_spec(spec: &ServerSpec) -> Self {
        DramModel { lat_ns: spec.dram_lat_ns, bw_gbs: spec.dram_bw_gbs }
    }

    /// Latency of one random 64B line access when `active_jobs` memory-
    /// intensive jobs share the socket. Queueing grows linearly with
    /// contenders (M/D/1-ish small-utilization regime).
    pub fn access_latency_ns(&self, active_jobs: usize) -> f64 {
        let extra = calib::DRAM_CONTENTION_ALPHA * active_jobs.saturating_sub(1) as f64;
        self.lat_ns * (1.0 + extra)
    }

    /// Streaming time for `bytes` of sequential traffic under fair
    /// bandwidth sharing, capped by the per-core limit.
    pub fn stream_ns(&self, bytes: u64, active_jobs: usize) -> f64 {
        let share =
            (self.bw_gbs / active_jobs.max(1) as f64).min(calib::PER_CORE_DRAM_BW_GBS);
        bytes as f64 / share // GB/s == bytes/ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerSpec;

    #[test]
    fn contention_raises_latency() {
        let d = DramModel::from_spec(&ServerSpec::broadwell());
        assert!(d.access_latency_ns(8) > d.access_latency_ns(1));
        assert_eq!(d.access_latency_ns(1), 80.0);
    }

    #[test]
    fn haswell_slower_than_broadwell() {
        let h = DramModel::from_spec(&ServerSpec::haswell());
        let b = DramModel::from_spec(&ServerSpec::broadwell());
        assert!(h.access_latency_ns(1) > b.access_latency_ns(1));
        assert!(h.stream_ns(1 << 20, 1) >= b.stream_ns(1 << 20, 1));
    }

    #[test]
    fn stream_respects_per_core_cap() {
        let d = DramModel::from_spec(&ServerSpec::skylake());
        // 1 GB at the 14 GB/s cap = ~71.4 ms even though socket has 85.
        let ns = d.stream_ns(1_000_000_000, 1);
        assert!((ns / 1e6 - 71.4).abs() < 1.0, "{} ms", ns / 1e6);
    }

    #[test]
    fn sharing_divides_bandwidth() {
        let d = DramModel::from_spec(&ServerSpec::broadwell());
        // 11 jobs: 77/11 = 7 GB/s per job, below the cap.
        assert!(d.stream_ns(1 << 20, 11) > 1.5 * d.stream_ns(1 << 20, 1));
    }
}
