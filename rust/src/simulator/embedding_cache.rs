//! Embedding-row software cache study — the paper's §VII future-work
//! direction ("use cases with fewer unique IDs enable opportunities for
//! embedding vector re-use and intelligent caching", citing Bandana):
//! simulate a dedicated row-granular cache in front of an embedding
//! table and measure hit rate across the Fig 14 locality spectrum.

use crate::workload::SparseIdGen;

use super::cache::Cache;

/// Result of one cache sizing point.
#[derive(Debug, Clone)]
pub struct CachePoint {
    pub cache_rows: usize,
    pub hit_rate: f64,
    pub lookups: usize,
}

/// Simulate an LRU row cache of `cache_rows` rows over `lookups` IDs
/// drawn from `gen`.
pub fn simulate_row_cache(gen: &mut SparseIdGen, cache_rows: usize, lookups: usize) -> CachePoint {
    // Row-granular: one "line" per row (64B line size is irrelevant
    // here; we use the Cache's line table as a row table).
    let mut cache = Cache::new((cache_rows * 64) as u64, 16.min(cache_rows.max(1)));
    let mut hits = 0usize;
    for _ in 0..lookups {
        let id = gen.next_id() as u64;
        if cache.probe(id) {
            hits += 1;
        } else {
            cache.insert(id);
        }
    }
    CachePoint { cache_rows, hit_rate: hits as f64 / lookups as f64, lookups }
}

/// Simulate the *serving-path* cache stream: `batches` batches of
/// `batch_lookups` IDs each, with per-batch deduplication. The sharded
/// leader resolves each distinct row at most once per batch (its
/// per-batch row map), so a repeat within a batch counts as a hit
/// regardless of cache state and only the first occurrence probes —
/// and, on a miss, fills — the cache. This is the predictor to compare
/// against measured `ShardedStats` hit rates; the sequential
/// `simulate_row_cache` charges every within-batch repeat to the cache
/// and under-predicts hot traces by up to ~0.23.
pub fn simulate_row_cache_batched(
    gen: &mut SparseIdGen,
    cache_rows: usize,
    batches: usize,
    batch_lookups: usize,
) -> CachePoint {
    let mut cache = Cache::new((cache_rows * 64) as u64, 16.min(cache_rows.max(1)));
    let mut hits = 0usize;
    let mut seen = std::collections::HashSet::new();
    for _ in 0..batches {
        seen.clear();
        for _ in 0..batch_lookups {
            let id = gen.next_id() as u64;
            if !seen.insert(id) {
                hits += 1; // resolved earlier in this batch
                continue;
            }
            if cache.probe(id) {
                hits += 1;
            } else {
                cache.insert(id);
            }
        }
    }
    let lookups = batches * batch_lookups;
    CachePoint { cache_rows, hit_rate: hits as f64 / lookups.max(1) as f64, lookups }
}

/// Sweep cache sizes (as fractions of the table) for one generator.
pub fn sweep_cache_sizes(
    mk_gen: impl Fn(u64) -> SparseIdGen,
    rows: usize,
    fractions: &[f64],
    lookups: usize,
) -> Vec<CachePoint> {
    fractions
        .iter()
        .map(|&f| {
            let cache_rows = ((rows as f64 * f) as usize).max(16);
            let mut gen = mk_gen(99);
            simulate_row_cache(&mut gen, cache_rows, lookups)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{IdDistribution, SparseIdGen};

    const ROWS: usize = 1_000_000;

    #[test]
    fn hot_traces_cache_well_uniform_does_not() {
        // The paper's caching claim: high-reuse use cases (low unique-ID
        // fraction) get high hit rates from a small cache; uniform
        // traffic does not.
        let mut hot = SparseIdGen::new(
            IdDistribution::Trace { hot_fraction: 0.001, hot_prob: 0.9 },
            ROWS,
            1,
        );
        let mut uni = SparseIdGen::new(IdDistribution::Uniform, ROWS, 1);
        let cache_rows = ROWS / 100; // 1% of the table
        let h = simulate_row_cache(&mut hot, cache_rows, 50_000);
        let u = simulate_row_cache(&mut uni, cache_rows, 50_000);
        assert!(h.hit_rate > 0.7, "hot trace hit rate {}", h.hit_rate);
        assert!(u.hit_rate < 0.1, "uniform hit rate {}", u.hit_rate);
    }

    #[test]
    fn hit_rate_monotone_in_cache_size() {
        let pts = sweep_cache_sizes(
            |seed| SparseIdGen::new(IdDistribution::Zipf { s: 1.05 }, ROWS, seed),
            ROWS,
            &[0.001, 0.01, 0.1],
            30_000,
        );
        assert!(pts[0].hit_rate <= pts[1].hit_rate + 0.02);
        assert!(pts[1].hit_rate <= pts[2].hit_rate + 0.02);
        assert!(pts[2].hit_rate > pts[0].hit_rate);
    }

    #[test]
    fn batched_dedup_raises_predicted_hit_rate_on_hot_traces() {
        // A hot trace repeats IDs *within* a batch; per-batch dedup
        // counts those as hits (the leader's row map serves them), so
        // the batched predictor must sit above the sequential one.
        let mk = || {
            SparseIdGen::new(
                IdDistribution::Trace { hot_fraction: 0.001, hot_prob: 0.9 },
                ROWS,
                7,
            )
        };
        let cache_rows = ROWS / 1000;
        let seq = simulate_row_cache(&mut mk(), cache_rows, 40_000);
        let bat = simulate_row_cache_batched(&mut mk(), cache_rows, 100, 400);
        assert_eq!(seq.lookups, bat.lookups, "same stream length");
        assert!(
            bat.hit_rate >= seq.hit_rate,
            "batched {} < sequential {}",
            bat.hit_rate,
            seq.hit_rate
        );
        // Uniform traffic has almost no within-batch repeats: the two
        // predictors agree.
        let mut u1 = SparseIdGen::new(IdDistribution::Uniform, ROWS, 7);
        let mut u2 = SparseIdGen::new(IdDistribution::Uniform, ROWS, 7);
        let useq = simulate_row_cache(&mut u1, cache_rows, 40_000);
        let ubat = simulate_row_cache_batched(&mut u2, cache_rows, 100, 400);
        assert!((useq.hit_rate - ubat.hit_rate).abs() < 0.01);
    }

    #[test]
    fn zipf_small_cache_beats_unique_fraction_baseline() {
        // Even a 0.1% cache captures the Zipf head.
        let mut gen = SparseIdGen::new(IdDistribution::Zipf { s: 1.05 }, ROWS, 3);
        let p = simulate_row_cache(&mut gen, ROWS / 1000, 50_000);
        assert!(p.hit_rate > 0.3, "zipf hit rate {}", p.hit_rate);
    }
}
