//! Three-level memory hierarchy with private L1/L2 per co-located
//! instance and one shared L3, supporting the paper's two inclusion
//! policies (§VI, Takeaway 7):
//!
//! * **Inclusive** (Haswell, Broadwell): every L2 line is also in L3;
//!   an L3 eviction *back-invalidates* the owner's L1/L2 copy. Under
//!   co-location, co-runners' L3 pressure therefore reaches into other
//!   instances' private caches — the mechanism behind Broadwell's
//!   latency cliffs (Figs 9-11).
//! * **Exclusive** (Skylake): L3 is a victim cache; L2 contents are not
//!   duplicated in L3 and cannot be back-invalidated by it.

use crate::config::{CacheInclusion, ServerSpec};
use crate::metrics::CacheCounters;

use super::cache::Cache;

/// Where an access was served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HitLevel {
    L1,
    L2,
    L3,
    Dram,
}

struct PrivateCaches {
    l1: Cache,
    l2: Cache,
}

pub struct SharedMemorySystem {
    privates: Vec<PrivateCaches>,
    l3: Cache,
    inclusion: CacheInclusion,
    /// Per-instance hit/miss accounting.
    pub counters: Vec<CacheCounters>,
}

/// Instance tag occupies the top byte of the line address; one
/// instance's lines can never alias another's.
const INST_SHIFT: u32 = 56;

impl SharedMemorySystem {
    pub fn new(spec: &ServerSpec, instances: usize) -> Self {
        assert!(instances >= 1 && instances < 256);
        let privates = (0..instances)
            .map(|_| PrivateCaches {
                l1: Cache::new(spec.l1_bytes(), 8),
                l2: Cache::new(spec.l2_bytes(), 8),
            })
            .collect();
        SharedMemorySystem {
            privates,
            l3: Cache::new(spec.l3_bytes(), 16),
            inclusion: spec.inclusion,
            counters: vec![CacheCounters::default(); instances],
        }
    }

    pub fn instances(&self) -> usize {
        self.privates.len()
    }

    fn owner_of(line: u64) -> usize {
        (line >> INST_SHIFT) as usize
    }

    /// Access one 64B line (byte `addr` within instance `inst`'s private
    /// address space). Returns the level that served it.
    pub fn access(&mut self, inst: usize, addr: u64) -> HitLevel {
        let line = ((inst as u64) << INST_SHIFT) | (addr >> 6);
        let p = &mut self.privates[inst];
        if p.l1.probe(line) {
            self.counters[inst].l1_hits += 1;
            return HitLevel::L1;
        }
        if p.l2.probe(line) {
            p.l1.insert(line);
            self.counters[inst].l2_hits += 1;
            return HitLevel::L2;
        }
        match self.inclusion {
            CacheInclusion::Inclusive => self.access_inclusive(inst, line),
            CacheInclusion::Exclusive => self.access_exclusive(inst, line),
        }
    }

    fn access_inclusive(&mut self, inst: usize, line: u64) -> HitLevel {
        let l3_hit = self.l3.probe(line);
        if l3_hit {
            let p = &mut self.privates[inst];
            p.l2.insert(line);
            p.l1.insert(line);
            self.counters[inst].l3_hits += 1;
            return HitLevel::L3;
        }
        // DRAM fill: install in all levels; L3 eviction back-invalidates
        // the victim owner's private copies.
        if let Some(victim) = self.l3.insert(line) {
            let owner = Self::owner_of(victim);
            if owner < self.privates.len() {
                let po = &mut self.privates[owner];
                if po.l2.invalidate(victim) {
                    self.counters[owner].l2_back_invalidations += 1;
                }
                po.l1.invalidate(victim);
            }
        }
        let p = &mut self.privates[inst];
        p.l2.insert(line);
        p.l1.insert(line);
        self.counters[inst].dram_accesses += 1;
        HitLevel::Dram
    }

    fn access_exclusive(&mut self, inst: usize, line: u64) -> HitLevel {
        let l3_hit = self.l3.probe(line);
        if l3_hit {
            // Move from L3 into L2 (exclusive); L2 victim falls to L3.
            self.l3.invalidate(line);
            let p = &mut self.privates[inst];
            if let Some(victim) = p.l2.insert(line) {
                self.l3.insert(victim);
            }
            p.l1.insert(line);
            self.counters[inst].l3_hits += 1;
            return HitLevel::L3;
        }
        // DRAM fill goes to L2 only; victim falls to L3.
        let p = &mut self.privates[inst];
        if let Some(victim) = p.l2.insert(line) {
            self.l3.insert(victim);
        }
        p.l1.insert(line);
        self.counters[inst].dram_accesses += 1;
        HitLevel::Dram
    }

    pub fn reset_counters(&mut self) {
        for c in &mut self.counters {
            *c = CacheCounters::default();
        }
        self.l3.reset_stats();
        for p in &mut self.privates {
            p.l1.reset_stats();
            p.l2.reset_stats();
        }
    }

    pub fn l3_stats(&self) -> super::cache::CacheStats {
        self.l3.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerSpec;

    fn tiny_spec(inclusion: CacheInclusion) -> ServerSpec {
        let mut s = ServerSpec::broadwell();
        s.l1_kb = 1; // 16 lines
        s.l2_kb = 4; // 64 lines
        s.l3_mb = 64.0 / (1024.0 * 1024.0) * 64.0; // 64 lines
        s.inclusion = inclusion;
        s
    }

    #[test]
    fn first_access_is_dram_second_is_l1() {
        let mut m = SharedMemorySystem::new(&ServerSpec::broadwell(), 1);
        assert_eq!(m.access(0, 0x1000), HitLevel::Dram);
        assert_eq!(m.access(0, 0x1000), HitLevel::L1);
        assert_eq!(m.counters[0].dram_accesses, 1);
        assert_eq!(m.counters[0].l1_hits, 1);
    }

    #[test]
    fn same_addr_different_instances_do_not_alias() {
        let mut m = SharedMemorySystem::new(&ServerSpec::broadwell(), 2);
        assert_eq!(m.access(0, 0x1000), HitLevel::Dram);
        assert_eq!(m.access(1, 0x1000), HitLevel::Dram);
        assert_eq!(m.access(0, 0x1000), HitLevel::L1);
    }

    #[test]
    fn inclusive_back_invalidation_reaches_private_l2() {
        // Instance 0 loads a line; instance 1 thrashes L3 until 0's line
        // is evicted from L3 -> it must also vanish from 0's L2.
        let mut m = SharedMemorySystem::new(&tiny_spec(CacheInclusion::Inclusive), 2);
        m.access(0, 0);
        assert_eq!(m.access(0, 0), HitLevel::L1);
        // Thrash far more lines than L3 holds.
        for i in 0..4096u64 {
            m.access(1, 0x10_0000 + i * 64);
        }
        // Instance 0's line was back-invalidated: next access misses all
        // levels even though its private L1/L2 saw no instance-0 traffic.
        assert_eq!(m.access(0, 0), HitLevel::Dram);
        assert!(m.counters[0].l2_back_invalidations > 0);
    }

    #[test]
    fn exclusive_hierarchy_shields_private_l2() {
        let mut m = SharedMemorySystem::new(&tiny_spec(CacheInclusion::Exclusive), 2);
        m.access(0, 0);
        for i in 0..4096u64 {
            m.access(1, 0x10_0000 + i * 64);
        }
        // L2 copy survives the co-runner's L3 thrashing.
        let lvl = m.access(0, 0);
        assert!(
            lvl == HitLevel::L1 || lvl == HitLevel::L2,
            "expected private hit, got {lvl:?}"
        );
        assert_eq!(m.counters[0].l2_back_invalidations, 0);
    }

    #[test]
    fn exclusive_l3_acts_as_victim_cache() {
        let mut m = SharedMemorySystem::new(&tiny_spec(CacheInclusion::Exclusive), 1);
        // Fill L2 (64 lines) and then some, so early lines spill to L3.
        for i in 0..80u64 {
            m.access(0, i * 64);
        }
        // Line 0 was evicted from L2 into L3: next access hits L3.
        let lvl = m.access(0, 0);
        assert!(lvl == HitLevel::L3 || lvl == HitLevel::L2, "got {lvl:?}");
    }

    #[test]
    fn working_set_within_l2_hits_after_warmup() {
        let mut m = SharedMemorySystem::new(&ServerSpec::skylake(), 1);
        let lines: Vec<u64> = (0..1000).map(|i| i * 64).collect(); // 64KB
        for &a in &lines {
            m.access(0, a);
        }
        m.reset_counters();
        for &a in &lines {
            let lvl = m.access(0, a);
            assert!(lvl != HitLevel::Dram);
        }
        assert_eq!(m.counters[0].dram_accesses, 0);
    }
}
