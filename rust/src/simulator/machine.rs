//! Operator-level timing of one inference on one modeled server.
//!
//! SLS is *trace-driven*: every gathered cache line runs through the
//! set-associative hierarchy (hierarchy.rs), so batching locality, Zipf
//! reuse, co-location pollution and inclusive back-invalidation all
//! emerge mechanistically. FC/BatchMatMul are *analytic* (roofline with
//! cache-residency): compute at the batch-dependent SIMD efficiency vs
//! weight streaming from wherever the weights fit. Element-wise glue ops
//! stream at a fixed cache bandwidth. Every operator pays the framework
//! dispatch overhead the paper's Caffe2 stack exhibits.

use std::collections::HashMap;

use crate::config::ServerSpec;
use crate::metrics::CacheCounters;
use crate::model::{ModelGraph, Op, OpCategory};
use crate::util::Rng;
use crate::workload::SparseIdGen;

use super::calib;
use super::core::CoreModel;
use super::dram::DramModel;
use super::hierarchy::{HitLevel, SharedMemorySystem};

/// Timing + accounting result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceBreakdown {
    pub total_ns: f64,
    pub by_cat: HashMap<OpCategory, f64>,
    /// Cache counter deltas attributable to this inference (SLS traces).
    pub counters: CacheCounters,
    /// Estimated dynamic instructions (for MPKI).
    pub instructions: u64,
}

impl InferenceBreakdown {
    pub fn ms(&self) -> f64 {
        self.total_ns / 1e6
    }

    pub fn cat_ns(&self, cat: OpCategory) -> f64 {
        *self.by_cat.get(&cat).unwrap_or(&0.0)
    }

    pub fn cat_frac(&self, cat: OpCategory) -> f64 {
        self.cat_ns(cat) / self.total_ns
    }

    pub fn llc_mpki(&self) -> f64 {
        self.counters.llc_misses() as f64 / (self.instructions as f64 / 1000.0).max(1e-9)
    }
}

/// One modeled server with `instances` co-located inference slots.
pub struct MachineSim {
    pub spec: ServerSpec,
    pub mem: SharedMemorySystem,
    pub dram: DramModel,
    pub core: CoreModel,
    rng: Rng,
    jitter_sigma: Option<f64>,
    /// Hyperthreading pair sharing the physical core (§VI).
    pub hyperthreading: bool,
}

impl MachineSim {
    pub fn new(spec: ServerSpec, instances: usize) -> Self {
        let mem = SharedMemorySystem::new(&spec, instances);
        let dram = DramModel::from_spec(&spec);
        let core = CoreModel::from_spec(&spec);
        MachineSim {
            spec,
            mem,
            dram,
            core,
            rng: Rng::seed_from_u64(0x5eed),
            jitter_sigma: None,
            hyperthreading: false,
        }
    }

    /// Enable production-environment latency jitter (Fig 11).
    pub fn with_production_jitter(mut self, seed: u64) -> Self {
        self.jitter_sigma = Some(calib::PRODUCTION_JITTER_SIGMA);
        self.rng = Rng::seed_from_u64(seed);
        self
    }

    pub fn with_hyperthreading(mut self, on: bool) -> Self {
        self.hyperthreading = on;
        self
    }

    fn jitter_factor(&mut self) -> f64 {
        match self.jitter_sigma {
            Some(sigma) => self.rng.lognormal(0.0, sigma),
            None => 1.0,
        }
    }

    /// Run one batch-`batch` inference of `graph` on instance slot
    /// `inst`, with `active_jobs` memory-intensive co-runners currently
    /// live on the machine (including this one).
    pub fn run_inference(
        &mut self,
        inst: usize,
        graph: &ModelGraph,
        batch: usize,
        idgen: &mut SparseIdGen,
        active_jobs: usize,
    ) -> InferenceBreakdown {
        assert!(batch >= 1);
        let active = active_jobs.max(1);
        let model_fc_bytes: u64 = graph
            .ops
            .iter()
            .filter(|o| matches!(o, Op::Fc { .. } | Op::BatchMatMul { .. }))
            .map(|o| o.weight_bytes())
            .sum();

        let mut by_cat: HashMap<OpCategory, f64> = HashMap::new();
        let mut total_ns = 0.0;
        let mut instructions = 0u64;
        let before = self.mem.counters[inst];

        let mut sls_index = 0usize;
        let mut fc_index = 0usize;
        for op in &graph.ops {
            let (ns, instr) = match op {
                Op::Fc { .. } | Op::BatchMatMul { .. } | Op::Conv2d { .. } | Op::LstmCell { .. } => {
                    fc_index += 1;
                    self.time_compute_op(inst, fc_index - 1, op, batch, model_fc_bytes, active)
                }
                Op::Sls { rows, emb_dim, lookups } => {
                    let r = self.time_sls(
                        inst, sls_index, *rows, *emb_dim, *lookups, batch, idgen, active,
                    );
                    sls_index += 1;
                    r
                }
                Op::Concat { .. } | Op::Relu { .. } | Op::Sigmoid { .. } => {
                    self.time_elementwise(op, batch)
                }
            };
            let ns = ns * self.jitter_factor();
            *by_cat.entry(op.category()).or_default() += ns;
            total_ns += ns;
            instructions += instr;
        }

        let mut counters = self.mem.counters[inst];
        // Delta since entry.
        counters.l1_hits -= before.l1_hits;
        counters.l2_hits -= before.l2_hits;
        counters.l3_hits -= before.l3_hits;
        counters.dram_accesses -= before.dram_accesses;
        counters.l2_back_invalidations -= before.l2_back_invalidations;

        InferenceBreakdown { total_ns, by_cat, counters, instructions }
    }

    /// Roofline timing for FC-like ops. The private-L2-covered weight
    /// slice streams for free (hidden under compute); the *uncovered*
    /// remainder is TRACE-DRIVEN through the shared hierarchy, so
    /// co-runner pollution, inclusive back-invalidation, and capacity
    /// effects all reach FC mechanistically. This is the mechanism
    /// behind Fig 11: a 1MB FC fits Skylake's (1MB) L2 and is insulated
    /// from co-runners, but only fits Broadwell's LLC and is exposed.
    fn time_compute_op(
        &mut self,
        inst: usize,
        fc_idx: usize,
        op: &Op,
        batch: usize,
        model_fc_bytes: u64,
        active: usize,
    ) -> (f64, u64) {
        let _ = (inst, fc_idx); // reserved for trace-driven FC experiments
        let flops = op.flops(batch) as f64;
        let weights = op.weight_bytes();
        // Recurrent cells re-stream weights every time step (Fig 5).
        let passes = match op {
            Op::LstmCell { steps, .. } => *steps,
            _ => 1,
        };

        let mut compute_ns = flops / self.core.effective_gflops(batch);
        if self.hyperthreading {
            compute_ns *= calib::HT_FC_PENALTY;
        }

        let l2_avail = (self.spec.l2_bytes() as f64 * calib::L2_USABLE_FRACTION) as u64;
        let uncovered = weights.saturating_sub(l2_avail);
        let mem_ns = if uncovered == 0 {
            0.0
        } else {
            // L3 residency of the uncovered slice between invocations:
            // (a) capacity — the op's share of usable L3 against the
            //     model's total uncovered weight footprint; and
            // (b) survival — co-runners stream CO_RUNNER_TRAFFIC_MB of
            //     L3 traffic between invocations, evicting this op's
            //     lines with probability 1 - exp(-traffic / L3).
            // Skylake's 1MB L2 covers small FCs entirely (insulated);
            // Broadwell's 256KB L2 leaves them exposed — Fig 11.
            let l3_usable = self.spec.l3_bytes() as f64 * calib::L3_USABLE_FRACTION;
            let l3_share = l3_usable / active as f64;
            let model_uncovered =
                model_fc_bytes.saturating_sub(l2_avail).max(uncovered) as f64;
            let capacity = (l3_share / model_uncovered).min(1.0);
            let traffic = (active - 1) as f64 * calib::CO_RUNNER_TRAFFIC_MB * 1e6;
            let survival = (-traffic / l3_usable).exp();
            let resident = capacity * survival;
            let from_l3 = uncovered as f64 * resident;
            let from_dram = uncovered as f64 * (1.0 - resident);
            let dram_share =
                (self.dram.bw_gbs / active as f64).min(calib::PER_CORE_DRAM_BW_GBS);
            passes as f64
                * (from_l3 / self.spec.l3_bw_gbs + from_dram / dram_share)
        };

        // Partial overlap: streaming is mostly prefetchable but not
        // fully hidden; contention on the exposed fraction is what
        // degrades compute-bound models under co-location (Fig 9 RMC3).
        let ns = compute_ns
            + calib::FC_MEM_EXPOSED_FRACTION * mem_ns
            + calib::DISPATCH_OVERHEAD_NS;
        // Instruction estimate: packed FMA count / utilization overhead.
        // Deliberately ISA-independent (8-lane reference) so MPKI is
        // comparable across machines, as the paper's same-binary
        // measurements are.
        let instr = (flops / 16.0 * 1.35) as u64;
        (ns, instr)
    }

    /// Trace-driven SLS timing: every line goes through the hierarchy.
    #[allow(clippy::too_many_arguments)]
    fn time_sls(
        &mut self,
        inst: usize,
        table_idx: usize,
        rows: usize,
        emb_dim: usize,
        lookups: usize,
        batch: usize,
        idgen: &mut SparseIdGen,
        active: usize,
    ) -> (f64, u64) {
        let row_bytes = (emb_dim * 4) as u64;
        let lines_per_row = row_bytes.div_ceil(64).max(1);
        let base = ((table_idx as u64) + 1) << 36;
        let table_bytes = rows as u64 * row_bytes;

        // TLB: probability one row gather misses the DTLB.
        let p_tlb = (1.0 - self.spec.tlb_reach_bytes as f64 / table_bytes as f64)
            .clamp(0.0, 1.0);

        let dram_lat = self.dram.access_latency_ns(active);
        // Scalar loop overhead per lookup, at the core's base clock.
        let scalar_ns = calib::SLS_SCALAR_CYCLES_PER_LOOKUP / self.spec.freq_ghz;
        let mut ns = 0.0;
        for _ in 0..batch {
            for _ in 0..lookups {
                ns += scalar_ns;
                let id = idgen.next_id() as u64 % rows as u64;
                let addr = base + id * row_bytes;
                let first = self.mem.access(inst, addr);
                ns += match first {
                    HitLevel::L1 => self.spec.l1_lat_ns,
                    HitLevel::L2 => self.spec.l2_lat_ns,
                    HitLevel::L3 => self.spec.l3_lat_ns,
                    HitLevel::Dram => dram_lat + p_tlb * self.spec.tlb_miss_ns,
                };
                for extra in 1..lines_per_row {
                    let lvl = self.mem.access(inst, addr + extra * 64);
                    ns += match lvl {
                        HitLevel::L1 => self.spec.l1_lat_ns,
                        HitLevel::L2 => self.spec.l2_lat_ns,
                        HitLevel::L3 => self.spec.l3_lat_ns,
                        // Adjacent-line prefetch: bandwidth-ish cost.
                        HitLevel::Dram => calib::ADJACENT_LINE_NS,
                    };
                }
            }
        }
        ns /= calib::SLS_MLP_FACTOR;
        if self.hyperthreading {
            ns *= calib::HT_SLS_PENALTY;
        }
        ns += calib::DISPATCH_OVERHEAD_NS;

        // ~ (vector adds per row) + index/loop overhead per lookup.
        // ISA-independent (8-lane reference) so cross-machine MPKI is
        // apples-to-apples.
        let instr = (batch * lookups * (emb_dim.div_ceil(8) * 2 + 8)) as u64;
        (ns, instr)
    }

    fn time_elementwise(&mut self, op: &Op, batch: usize) -> (f64, u64) {
        let bytes = (op.bytes_read(batch) + op.bytes_written(batch)) as f64;
        let ns = bytes / calib::ELEMENTWISE_BW_GBS + calib::DISPATCH_OVERHEAD_NS;
        let instr = (bytes / 16.0) as u64;
        (ns, instr)
    }

    /// Time a single standalone operator (Fig 11's focal FC) under the
    /// current cache state and `active` co-runners. The focal op runs on
    /// instance slot 0; its weights get a dedicated address region.
    pub fn time_op(&mut self, op: &Op, batch: usize, active: usize) -> f64 {
        let fc_idx = match op {
            Op::Fc { d_in, d_out } => 0x1000 + (d_in * 31 + d_out) % 0x1000,
            _ => 0x1000,
        };
        let (ns, _) = self.time_compute_op(0, fc_idx, op, batch, op.weight_bytes(), active);
        ns * self.jitter_factor()
    }

    /// Warm the caches with `n` inferences (not measured).
    pub fn warmup(
        &mut self,
        inst: usize,
        graph: &ModelGraph,
        batch: usize,
        idgen: &mut SparseIdGen,
        n: usize,
    ) {
        for _ in 0..n {
            self.run_inference(inst, graph, batch, idgen, self.mem.instances());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, ServerSpec};
    use crate::workload::SparseIdGen;

    fn run_once(spec: ServerSpec, cfg: &crate::config::RmcConfig, batch: usize) -> InferenceBreakdown {
        let graph = ModelGraph::from_rmc(cfg);
        let mut m = MachineSim::new(spec, 1);
        let mut gen = SparseIdGen::production_like(cfg.rows, 7);
        m.warmup(0, &graph, batch, &mut gen, 3);
        m.run_inference(0, &graph, batch, &mut gen, 1)
    }

    #[test]
    fn rmc_latency_ordering_unit_batch() {
        // Fig 7: RMC1 < RMC2 < RMC3 at unit batch on Broadwell.
        let l1 = run_once(ServerSpec::broadwell(), &presets::rmc1_small(), 1).ms();
        let l2 = run_once(ServerSpec::broadwell(), &presets::rmc2_small(), 1).ms();
        let l3 = run_once(ServerSpec::broadwell(), &presets::rmc3_small(), 1).ms();
        assert!(l1 < l2, "rmc1 {l1} !< rmc2 {l2}");
        assert!(l2 < l3, "rmc2 {l2} !< rmc3 {l3}");
    }

    #[test]
    fn rmc2_is_sls_dominated_rmc3_is_fc_dominated() {
        // Fig 7 right: RMC2 ~80% SLS; RMC3 >= 96% FC.
        let b2 = run_once(ServerSpec::broadwell(), &presets::rmc2_small(), 1);
        let b3 = run_once(ServerSpec::broadwell(), &presets::rmc3_small(), 1);
        assert!(b2.cat_frac(OpCategory::Sls) > 0.5, "rmc2 sls frac {}", b2.cat_frac(OpCategory::Sls));
        assert!(b3.cat_frac(OpCategory::Fc) > 0.85, "rmc3 fc frac {}", b3.cat_frac(OpCategory::Fc));
    }

    #[test]
    fn batching_amortizes_per_item_cost() {
        let l1 = run_once(ServerSpec::broadwell(), &presets::rmc1_small(), 1).total_ns;
        let l128 = run_once(ServerSpec::broadwell(), &presets::rmc1_small(), 128).total_ns;
        assert!(l128 / 128.0 < l1, "per-item batched should be cheaper");
    }

    #[test]
    fn counters_track_sls_misses() {
        let b = run_once(ServerSpec::broadwell(), &presets::rmc2_small(), 4);
        assert!(b.counters.dram_accesses > 0, "cold tables must miss");
        assert!(b.instructions > 0);
        assert!(b.llc_mpki() > 0.5, "mpki {}", b.llc_mpki());
    }

    #[test]
    fn hyperthreading_slows_everything() {
        let graph = ModelGraph::from_rmc(&presets::rmc3_small());
        let cfg = presets::rmc3_small();
        let mut a = MachineSim::new(ServerSpec::broadwell(), 1);
        let mut b = MachineSim::new(ServerSpec::broadwell(), 1).with_hyperthreading(true);
        let mut g1 = SparseIdGen::production_like(cfg.rows, 7);
        let mut g2 = SparseIdGen::production_like(cfg.rows, 7);
        let x = a.run_inference(0, &graph, 16, &mut g1, 1);
        let y = b.run_inference(0, &graph, 16, &mut g2, 1);
        assert!(y.total_ns > 1.3 * x.total_ns);
    }

    #[test]
    fn jitter_is_reproducible_per_seed() {
        let graph = ModelGraph::from_rmc(&presets::rmc1_small());
        let cfg = presets::rmc1_small();
        let run = |seed| {
            let mut m =
                MachineSim::new(ServerSpec::broadwell(), 1).with_production_jitter(seed);
            let mut g = SparseIdGen::production_like(cfg.rows, 3);
            m.run_inference(0, &graph, 8, &mut g, 1).total_ns
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
