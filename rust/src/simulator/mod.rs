//! Architectural simulator — the substituted Intel testbed (DESIGN.md §3).
//!
//! Models exactly the mechanisms the paper's cross-architecture analysis
//! names: clock frequency, AVX-2/AVX-512 throughput with batch-dependent
//! utilization (§V), three-level set-associative caches with inclusive
//! (back-invalidating) vs exclusive L2/L3 policies (§VI, Takeaway 7),
//! DDR3/DDR4 latency/bandwidth (Takeaway 3), TLB reach, shared-LLC and
//! shared-DRAM co-location contention, and framework dispatch overhead.
//!
//! Calibration constants live in `calib.rs`; EXPERIMENTS.md records how
//! well the calibrated model matches every paper number.

pub mod cache;
pub mod calib;
pub mod colocation;
pub mod core;
pub mod distributed;
pub mod dram;
pub mod embedding_cache;
pub mod hierarchy;
pub mod machine;

pub use cache::Cache;
pub use colocation::{ColocationResult, ColocationSim};
pub use core::CoreModel;
pub use dram::DramModel;
pub use hierarchy::{HitLevel, SharedMemorySystem};
pub use machine::{InferenceBreakdown, MachineSim};
