//! Hand-rolled bench harness — std-only stand-in for criterion
//! (unavailable offline). Used by the `benches/` binaries (harness =
//! false): warm-up, repeated timed runs, mean/p50/min/max reporting.

use std::time::Instant;

use super::json::Json;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.1} us/iter (median {:.1}, min {:.1}, max {:.1}; n={})",
            self.name,
            self.mean_ns / 1e3,
            self.median_ns / 1e3,
            self.min_ns / 1e3,
            self.max_ns / 1e3,
            self.iters
        )
    }

    /// Machine-readable form for the committed BENCH_*.json trackers.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("mean_ns".into(), Json::Num(self.mean_ns.round()));
        m.insert("median_ns".into(), Json::Num(self.median_ns.round()));
        m.insert("min_ns".into(), Json::Num(self.min_ns.round()));
        m.insert("max_ns".into(), Json::Num(self.max_ns.round()));
        Json::Obj(m)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        name: name.into(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
    }
}

/// Section header for bench output.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 1, 20, || { std::hint::black_box(1 + 1); });
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.mean_ns > 0.0);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn report_contains_name() {
        let s = bench("myname", 0, 2, || {});
        assert!(s.report().contains("myname"));
    }

    #[test]
    fn json_form_carries_fields() {
        let s = bench("jname", 0, 3, || {
            std::hint::black_box(2 + 2);
        });
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("jname"));
        assert_eq!(j.get("iters").unwrap().as_usize(), Some(3));
        assert!(j.get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
    }
}
