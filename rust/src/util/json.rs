//! Minimal JSON parser + writer — std-only replacement for serde_json
//! (unavailable in the offline registry). Supports the full JSON value
//! model; used for `artifacts/manifest.json` and deployment configs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ------------------------------------------------ accessors -------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the path (manifest loading).
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------ writer ----------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Emitter helper: a finite number, or null — percentiles of an empty
/// slice are NaN, and `NaN` is not valid JSON. One policy, used by
/// every report/bench emitter.
pub fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// Emitter helper: build an object from (key, value) pairs.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"batch": 8, "golden": [0.5, 0.25], "name": "rmc1", "ok": true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("variants").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
