//! Std-only utility layer replacing unavailable ecosystem crates (see
//! Cargo.toml note): deterministic RNG + distributions (`rng`), a minimal
//! JSON parser for the artifact manifest and config files (`json`), a
//! micro property-testing helper (`prop`), and the bench timing harness
//! (`bench`).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
