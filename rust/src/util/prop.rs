//! Micro property-testing helper — std-only stand-in for proptest
//! (unavailable offline). Sweeps `cases` randomized inputs drawn from a
//! seeded RNG through a checker; on failure it reports the failing seed
//! so the case can be replayed deterministically.

use super::rng::Rng;

/// Run `cases` property checks. `f` gets a per-case RNG and the case
/// index; it should panic (assert) on property violation.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case)
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Draw a usize in [lo, hi] inclusive.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.gen_range((hi - lo + 1) as u64) as usize
}

/// Draw an f64 in [lo, hi).
pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.gen_f64() * (hi - lo)
}

/// Pick one element of a slice.
pub fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.gen_range(xs.len() as u64) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_, _| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 10, |rng, _| {
            assert!(rng.gen_f64() < 2.0); // always true
            assert!(false, "boom");
        });
    }

    #[test]
    fn helpers_in_bounds() {
        check("bounds", 50, |rng, _| {
            let u = usize_in(rng, 3, 7);
            assert!((3..=7).contains(&u));
            let f = f64_in(rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let p = *pick(rng, &[1, 2, 3]);
            assert!((1..=3).contains(&p));
        });
    }
}
