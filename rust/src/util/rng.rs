//! Deterministic xoshiro256++ PRNG plus the distributions the simulator
//! and workload generators need (uniform, exponential, normal,
//! log-normal, binomial, approximate Zipf). Std-only replacement for the
//! rand/rand_distr crates (unavailable in the offline registry).

/// xoshiro256++ seeded via splitmix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached spare normal (Box-Muller generates pairs).
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] (safe for ln()).
    pub fn gen_f64_open(&mut self) -> f64 {
        1.0 - self.gen_f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's method with rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.gen_f64_open().ln() / lambda
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.gen_f64_open();
        let u2 = self.gen_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Log-normal with ln-space mean `mu` and std `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Binomial(n, p) — exact via Bernoulli sum (n is small here: the
    /// co-location degree, <= ~40).
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        (0..n).filter(|_| self.gen_bool(p)).count() as u64
    }

    /// Approximate Zipf over ranks 1..=n with exponent `s` (> 0), via the
    /// continuous inverse-CDF: exact head concentration behaviour, small
    /// bias in the deep tail — fine for workload popularity modeling.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1 && s > 0.0);
        let u = self.gen_f64_open();
        let x = if (s - 1.0).abs() < 1e-9 {
            (n as f64).powf(u)
        } else {
            let one_s = 1.0 - s;
            ((u * ((n as f64).powf(one_s) - 1.0)) + 1.0).powf(1.0 / one_s)
        };
        (x.floor() as u64).clamp(1, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(
            Rng::seed_from_u64(1).next_u64(),
            Rng::seed_from_u64(2).next_u64()
        );
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::seed_from_u64(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn binomial_mean() {
        let mut r = Rng::seed_from_u64(7);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.binomial(20, 0.3) as f64).sum::<f64>() / n as f64;
        assert!((mean - 6.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn zipf_head_heavy() {
        let mut r = Rng::seed_from_u64(8);
        let n = 50_000;
        let head = (0..n).filter(|_| r.zipf(1_000_000, 1.1) <= 100).count();
        // With s=1.1 the top-100 ranks should absorb a large share.
        assert!(head as f64 / n as f64 > 0.3, "head share {}", head as f64 / n as f64);
        // All samples in range.
        for _ in 0..1000 {
            let z = r.zipf(50, 0.9);
            assert!((1..=50).contains(&z));
        }
    }

    #[test]
    fn lognormal_positive_centered() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..1000 {
            let v = r.lognormal(0.0, 0.05);
            assert!(v > 0.0 && (0.7..1.4).contains(&v));
        }
    }
}
