//! Poisson arrival process for the serving experiments (open-loop load).

use crate::util::Rng;

/// Exponential inter-arrival generator at `rate_qps` queries/second.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rate_qps: f64,
    rng: Rng,
    /// Running absolute arrival time, seconds.
    t: f64,
}

impl PoissonArrivals {
    pub fn new(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "rate must be positive");
        PoissonArrivals { rate_qps, rng: Rng::seed_from_u64(seed), t: 0.0 }
    }

    /// Next absolute arrival time in seconds.
    pub fn next_arrival_s(&mut self) -> f64 {
        self.t += self.rng.exp(self.rate_qps);
        self.t
    }

    /// All arrivals up to `horizon_s`.
    pub fn schedule(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival_s();
            if t > horizon_s {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rate_close() {
        let mut p = PoissonArrivals::new(1000.0, 9);
        let arr = p.schedule(10.0);
        let rate = arr.len() as f64 / 10.0;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotonic() {
        let mut p = PoissonArrivals::new(50.0, 1);
        let arr = p.schedule(5.0);
        for w in arr.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = PoissonArrivals::new(10.0, 4).schedule(2.0);
        let b = PoissonArrivals::new(10.0, 4).schedule(2.0);
        assert_eq!(a, b);
    }
}
