//! Arrival processes for the serving experiments (open-loop load):
//! homogeneous Poisson at a fixed rate, and non-homogeneous Poisson
//! against a piecewise-constant [`RatePlan`] (diurnal ramps, flash
//! crowds) via thinning.

use crate::util::Rng;

/// Exponential inter-arrival generator at `rate_qps` queries/second.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rate_qps: f64,
    rng: Rng,
    /// Running absolute arrival time, seconds.
    t: f64,
}

impl PoissonArrivals {
    pub fn new(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "rate must be positive");
        PoissonArrivals { rate_qps, rng: Rng::seed_from_u64(seed), t: 0.0 }
    }

    /// Next absolute arrival time in seconds.
    pub fn next_arrival_s(&mut self) -> f64 {
        self.t += self.rng.exp(self.rate_qps);
        self.t
    }

    /// All arrivals up to `horizon_s`.
    pub fn schedule(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival_s();
            if t > horizon_s {
                break;
            }
            out.push(t);
        }
        out
    }
}

/// Piecewise-constant offered-load plan: `(start_s, rate_qps)` segments
/// in ascending start order. The rate at time `t` is the rate of the
/// last segment whose start is ≤ `t`; the plan is flat at the final
/// segment's rate forever after. Constructors cover the two adversarial
/// shapes the autotune bench needs (diurnal ramp, flash crowd).
#[derive(Debug, Clone)]
pub struct RatePlan {
    segments: Vec<(f64, f64)>,
}

impl RatePlan {
    /// Flat plan — equivalent load to `PoissonArrivals::new(rate, _)`.
    pub fn constant(rate_qps: f64) -> Self {
        Self::segments(vec![(0.0, rate_qps)])
    }

    /// Explicit segment list. Panics on empty plans, segments before
    /// t=0, non-ascending starts, or non-positive rates (an offered-load
    /// plan with a zero-rate tail would hang an open-loop driver that
    /// asks for N queries).
    pub fn segments(segments: Vec<(f64, f64)>) -> Self {
        assert!(!segments.is_empty(), "rate plan needs at least one segment");
        assert!(segments[0].0 <= 0.0 + 1e-12, "first segment must start at t=0");
        for w in segments.windows(2) {
            assert!(w[1].0 > w[0].0, "segment starts must ascend");
        }
        assert!(segments.iter().all(|&(_, r)| r > 0.0), "rates must be positive");
        RatePlan { segments }
    }

    /// Diurnal-style ramp: `steps` equal-duration risers from `from` to
    /// `to` qps over `duration_s`, then flat at `to`.
    pub fn ramp(from_qps: f64, to_qps: f64, duration_s: f64, steps: usize) -> Self {
        assert!(steps >= 1 && duration_s > 0.0);
        let segs = (0..=steps)
            .map(|i| {
                let frac = i as f64 / steps as f64;
                (frac * duration_s, from_qps + frac * (to_qps - from_qps))
            })
            .collect();
        Self::segments(segs)
    }

    /// Flash crowd: `base` qps, spiking to `burst` qps for
    /// `[at_s, at_s + duration_s)`, then back to `base`.
    pub fn flash_crowd(base_qps: f64, burst_qps: f64, at_s: f64, duration_s: f64) -> Self {
        assert!(at_s > 0.0 && duration_s > 0.0);
        Self::segments(vec![(0.0, base_qps), (at_s, burst_qps), (at_s + duration_s, base_qps)])
    }

    /// Parse a CLI rate-plan spec (the `loadgen --rate-plan` flag):
    ///
    /// - `constant:QPS`
    /// - `ramp:FROM:TO:DURATION_S:STEPS`
    /// - `flash:BASE:BURST:AT_S:DURATION_S`
    /// - `segments:T0=R0,T1=R1,...` (explicit piecewise-constant plan)
    ///
    /// Errors (instead of panicking) on malformed specs, so a typo'd
    /// flag is a usage message, not a crash.
    pub fn parse(spec: &str) -> anyhow::Result<RatePlan> {
        let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
        let nums = |s: &str| -> anyhow::Result<Vec<f64>> {
            s.split(':')
                .map(|x| {
                    x.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("bad number '{x}' in rate plan '{spec}'"))
                })
                .collect()
        };
        match kind {
            "constant" => {
                let v = nums(rest)?;
                anyhow::ensure!(v.len() == 1, "constant takes one rate: 'constant:QPS'");
                anyhow::ensure!(v[0] > 0.0, "rate must be positive");
                Ok(RatePlan::constant(v[0]))
            }
            "ramp" => {
                let v = nums(rest)?;
                anyhow::ensure!(v.len() == 4, "ramp takes 'ramp:FROM:TO:DURATION_S:STEPS'");
                let steps = v[3] as usize;
                anyhow::ensure!(v[0] > 0.0 && v[1] > 0.0, "rates must be positive");
                anyhow::ensure!(v[2] > 0.0, "duration must be positive");
                anyhow::ensure!(steps >= 1 && v[3].fract() == 0.0, "steps must be an integer >= 1");
                Ok(RatePlan::ramp(v[0], v[1], v[2], steps))
            }
            "flash" => {
                let v = nums(rest)?;
                anyhow::ensure!(v.len() == 4, "flash takes 'flash:BASE:BURST:AT_S:DURATION_S'");
                anyhow::ensure!(v[0] > 0.0 && v[1] > 0.0, "rates must be positive");
                anyhow::ensure!(v[2] > 0.0 && v[3] > 0.0, "at/duration must be positive");
                Ok(RatePlan::flash_crowd(v[0], v[1], v[2], v[3]))
            }
            "segments" => {
                let mut segs = Vec::new();
                for part in rest.split(',') {
                    let (t, r) = part
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("segment '{part}' is not 'T=RATE'"))?;
                    let t: f64 = t
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad segment start '{t}' in '{spec}'"))?;
                    let r: f64 = r
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad segment rate '{r}' in '{spec}'"))?;
                    segs.push((t, r));
                }
                anyhow::ensure!(!segs.is_empty(), "segments plan needs at least one segment");
                anyhow::ensure!(segs[0].0 <= 1e-12, "first segment must start at t=0");
                for w in segs.windows(2) {
                    anyhow::ensure!(w[1].0 > w[0].0, "segment starts must ascend");
                }
                anyhow::ensure!(segs.iter().all(|&(_, r)| r > 0.0), "rates must be positive");
                Ok(RatePlan::segments(segs))
            }
            _ => anyhow::bail!(
                "unknown rate plan '{spec}' (want constant:QPS, ramp:FROM:TO:DUR:STEPS, \
                 flash:BASE:BURST:AT:DUR, or segments:T0=R0,...)"
            ),
        }
    }

    /// Offered rate at absolute time `t` seconds.
    pub fn rate_at(&self, t: f64) -> f64 {
        self.segments
            .iter()
            .rev()
            .find(|&&(start, _)| start <= t)
            .map(|&(_, r)| r)
            .unwrap_or(self.segments[0].1)
    }

    /// Peak rate — the thinning envelope.
    pub fn max_rate(&self) -> f64 {
        self.segments.iter().map(|&(_, r)| r).fold(f64::MIN, f64::max)
    }
}

/// Non-homogeneous Poisson arrivals against a [`RatePlan`], generated
/// by thinning: candidates at the envelope rate `max_rate`, each kept
/// with probability `rate_at(t) / max_rate`. Deterministic given the
/// seed, like [`PoissonArrivals`].
#[derive(Debug, Clone)]
pub struct ScheduledArrivals {
    plan: RatePlan,
    rng: Rng,
    t: f64,
}

impl ScheduledArrivals {
    pub fn new(plan: RatePlan, seed: u64) -> Self {
        ScheduledArrivals { plan, rng: Rng::seed_from_u64(seed), t: 0.0 }
    }

    /// Next absolute arrival time in seconds.
    pub fn next_arrival_s(&mut self) -> f64 {
        let envelope = self.plan.max_rate();
        loop {
            self.t += self.rng.exp(envelope);
            let keep = self.plan.rate_at(self.t) / envelope;
            if self.rng.gen_f64() < keep {
                return self.t;
            }
        }
    }

    /// All arrivals up to `horizon_s`.
    pub fn schedule(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival_s();
            if t > horizon_s {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rate_close() {
        let mut p = PoissonArrivals::new(1000.0, 9);
        let arr = p.schedule(10.0);
        let rate = arr.len() as f64 / 10.0;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotonic() {
        let mut p = PoissonArrivals::new(50.0, 1);
        let arr = p.schedule(5.0);
        for w in arr.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = PoissonArrivals::new(10.0, 4).schedule(2.0);
        let b = PoissonArrivals::new(10.0, 4).schedule(2.0);
        assert_eq!(a, b);
    }

    #[test]
    fn rate_plan_lookup_and_envelope() {
        let plan = RatePlan::flash_crowd(100.0, 900.0, 2.0, 0.5);
        assert_eq!(plan.rate_at(0.0), 100.0);
        assert_eq!(plan.rate_at(1.99), 100.0);
        assert_eq!(plan.rate_at(2.0), 900.0);
        assert_eq!(plan.rate_at(2.49), 900.0);
        assert_eq!(plan.rate_at(2.5), 100.0);
        assert_eq!(plan.rate_at(100.0), 100.0);
        assert_eq!(plan.max_rate(), 900.0);
        let ramp = RatePlan::ramp(100.0, 500.0, 4.0, 4);
        assert_eq!(ramp.rate_at(0.0), 100.0);
        assert_eq!(ramp.rate_at(2.0), 300.0);
        assert_eq!(ramp.rate_at(4.0), 500.0);
        assert_eq!(ramp.rate_at(99.0), 500.0, "flat at the final rate");
    }

    #[test]
    fn scheduled_arrivals_track_the_plan() {
        // Flat plan ≈ homogeneous Poisson at the same rate.
        let mut flat = ScheduledArrivals::new(RatePlan::constant(1000.0), 9);
        let n = flat.schedule(10.0).len() as f64 / 10.0;
        assert!((n - 1000.0).abs() < 100.0, "flat rate {n}");
        // Flash crowd: the burst second carries ~8x the base-rate load.
        let plan = RatePlan::flash_crowd(200.0, 1600.0, 4.0, 1.0);
        let arr = ScheduledArrivals::new(plan, 7).schedule(10.0);
        let base: usize = arr.iter().filter(|&&t| t < 4.0).count();
        let burst: usize = arr.iter().filter(|&&t| (4.0..5.0).contains(&t)).count();
        let base_rate = base as f64 / 4.0;
        assert!((base_rate - 200.0).abs() < 60.0, "base rate {base_rate}");
        assert!(
            (burst as f64 - 1600.0).abs() < 200.0,
            "burst second carried {burst} arrivals"
        );
    }

    #[test]
    fn parse_specs_match_constructors() {
        let p = RatePlan::parse("constant:500").unwrap();
        assert_eq!(p.rate_at(3.0), 500.0);
        let p = RatePlan::parse("ramp:100:500:4:4").unwrap();
        assert_eq!(p.rate_at(2.0), 300.0);
        assert_eq!(p.rate_at(99.0), 500.0);
        let p = RatePlan::parse("flash:200:1600:4:1").unwrap();
        assert_eq!(p.rate_at(4.5), 1600.0);
        assert_eq!(p.rate_at(5.5), 200.0);
        let p = RatePlan::parse("segments:0=100,2=900,2.5=100").unwrap();
        assert_eq!(p.rate_at(2.2), 900.0);
        assert_eq!(p.max_rate(), 900.0);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "constant",
            "constant:0",
            "constant:-5",
            "constant:abc",
            "ramp:100:500:4",
            "ramp:100:500:4:0",
            "ramp:100:500:4:1.5",
            "flash:200:1600:0:1",
            "segments:",
            "segments:1=100",
            "segments:0=100,0=200",
            "segments:0=-1",
            "warble:1:2",
        ] {
            assert!(RatePlan::parse(bad).is_err(), "spec '{bad}' should be rejected");
        }
    }

    #[test]
    fn scheduled_arrivals_deterministic_and_monotonic() {
        let plan = RatePlan::ramp(50.0, 400.0, 5.0, 10);
        let a = ScheduledArrivals::new(plan.clone(), 11).schedule(8.0);
        let b = ScheduledArrivals::new(plan, 11).schedule(8.0);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
