//! Deterministic fault-injection schedules for the serving stack.
//!
//! A [`FaultPlan`] is an ordered list of kill/restart events for worker
//! threads and embedding-shard executors, each armed by a trigger — a
//! dispatched-batch count (`b<N>`) or elapsed wall-clock seconds since
//! the serving window opened (`t<SECS>`). The dispatcher polls the plan
//! every loop iteration and applies whatever has come due, so the same
//! spec against the same workload produces the same fault sequence:
//! batch-count triggers are exactly reproducible, elapsed triggers are
//! reproducible up to scheduler jitter.
//!
//! Spec grammar (the `serve --faults SPEC` argument):
//!
//! ```text
//! SPEC    := EVENT (',' EVENT)*
//! EVENT   := ACTION ':' ID '@' TRIGGER
//! ACTION  := kill-worker | restart-worker | kill-shard | restart-shard
//! TRIGGER := 'b' <u64>      fire once >= N batches have been dispatched
//!          | 't' <f64>      fire once >= SECS seconds have elapsed
//! ```
//!
//! Example: `kill-shard:1@b8,restart-shard:1@b24,kill-worker:0@t0.5`.

use std::fmt;

use anyhow::{bail, Context};

/// What a fault event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Kill a coordinator worker thread by id (queued batches fail fast).
    KillWorker(usize),
    /// Respawn a previously killed worker under the same id.
    RestartWorker(usize),
    /// Kill an embedding-shard executor by shard index (replicas cover).
    KillShard(usize),
    /// Re-materialize a killed shard from the parameter seed.
    RestartShard(usize),
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::KillWorker(id) => write!(f, "kill-worker:{id}"),
            FaultAction::RestartWorker(id) => write!(f, "restart-worker:{id}"),
            FaultAction::KillShard(id) => write!(f, "kill-shard:{id}"),
            FaultAction::RestartShard(id) => write!(f, "restart-shard:{id}"),
        }
    }
}

/// When a fault event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTrigger {
    /// Once the dispatcher has dispatched at least this many batches.
    Batches(u64),
    /// Once this many seconds have elapsed since the serving window opened.
    ElapsedSecs(f64),
}

impl fmt::Display for FaultTrigger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultTrigger::Batches(n) => write!(f, "b{n}"),
            FaultTrigger::ElapsedSecs(s) => write!(f, "t{s}"),
        }
    }
}

/// One scheduled fault: an action armed by a trigger.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// What happens when the trigger condition is met.
    pub action: FaultAction,
    /// The condition that arms the action.
    pub trigger: FaultTrigger,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.action, self.trigger)
    }
}

/// An ordered, deterministic schedule of fault events.
///
/// Events fire in spec order among those simultaneously due, so
/// `kill-shard:1@b8,kill-worker:0@b8` always kills the shard first.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan (no faults — the default serving behavior).
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style append, for tests and programmatic schedules.
    pub fn with(mut self, action: FaultAction, trigger: FaultTrigger) -> Self {
        self.events.push(FaultEvent { action, trigger });
        self
    }

    /// True when no events remain (either empty spec or all fired).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of events still pending.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The pending events, in spec order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Parse a `--faults` spec (see module docs for the grammar).
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut events = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (head, trig) = part
                .split_once('@')
                .with_context(|| format!("fault event '{part}': expected ACTION:ID@TRIGGER"))?;
            let (action_name, id) = head
                .split_once(':')
                .with_context(|| format!("fault event '{part}': expected ACTION:ID@TRIGGER"))?;
            let id: usize = id
                .parse()
                .with_context(|| format!("fault event '{part}': bad target id '{id}'"))?;
            let action = match action_name {
                "kill-worker" => FaultAction::KillWorker(id),
                "restart-worker" => FaultAction::RestartWorker(id),
                "kill-shard" => FaultAction::KillShard(id),
                "restart-shard" => FaultAction::RestartShard(id),
                other => bail!(
                    "fault event '{part}': unknown action '{other}' (expected kill-worker, \
                     restart-worker, kill-shard, or restart-shard)"
                ),
            };
            let trigger = match trig.split_at(trig.len().min(1)) {
                ("b", n) => FaultTrigger::Batches(
                    n.parse()
                        .with_context(|| format!("fault event '{part}': bad batch count '{n}'"))?,
                ),
                ("t", s) => {
                    let secs: f64 = s.parse().with_context(|| {
                        format!("fault event '{part}': bad elapsed seconds '{s}'")
                    })?;
                    if !secs.is_finite() || secs < 0.0 {
                        bail!("fault event '{part}': elapsed seconds must be finite and >= 0");
                    }
                    FaultTrigger::ElapsedSecs(secs)
                }
                _ => bail!(
                    "fault event '{part}': bad trigger '{trig}' (expected b<batches> or t<secs>)"
                ),
            };
            events.push(FaultEvent { action, trigger });
        }
        if events.is_empty() {
            bail!("fault spec '{spec}': no events");
        }
        Ok(FaultPlan { events })
    }

    /// Remove and return every event whose trigger is satisfied at the
    /// given progress point, preserving spec order. The dispatcher calls
    /// this once per loop iteration.
    pub fn take_due(&mut self, batches_dispatched: u64, elapsed_s: f64) -> Vec<FaultEvent> {
        let mut due = Vec::new();
        self.events.retain(|e| {
            let fire = match e.trigger {
                FaultTrigger::Batches(n) => batches_dispatched >= n,
                FaultTrigger::ElapsedSecs(t) => elapsed_s >= t,
            };
            if fire {
                due.push(*e);
            }
            !fire
        });
        due
    }

    /// Earliest pending elapsed-time trigger, if any — lets the
    /// dispatcher bound its receive timeout so time-armed faults fire
    /// promptly even on an idle channel.
    pub fn next_elapsed_trigger(&self) -> Option<f64> {
        self.events
            .iter()
            .filter_map(|e| match e.trigger {
                FaultTrigger::ElapsedSecs(t) => Some(t),
                FaultTrigger::Batches(_) => None,
            })
            .min_by(|a, b| a.partial_cmp(b).expect("finite by parse validation"))
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{e}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar_and_round_trips() {
        let spec = "kill-shard:1@b8,restart-shard:1@b24,kill-worker:0@t0.5,restart-worker:0@t1";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.len(), 4);
        assert_eq!(
            plan.events()[0],
            FaultEvent {
                action: FaultAction::KillShard(1),
                trigger: FaultTrigger::Batches(8),
            }
        );
        assert_eq!(
            plan.events()[2],
            FaultEvent {
                action: FaultAction::KillWorker(0),
                trigger: FaultTrigger::ElapsedSecs(0.5),
            }
        );
        // Round-trip through Display re-parses to the same plan.
        let echoed = FaultPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(echoed, plan);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "kill-worker:0",         // no trigger
            "kill-worker@b3",        // no id
            "explode:0@b3",          // unknown action
            "kill-worker:x@b3",      // bad id
            "kill-worker:0@3",       // bare trigger number
            "kill-worker:0@bx",      // bad batch count
            "kill-worker:0@t-1",     // negative elapsed
            "kill-worker:0@tnan",    // non-finite elapsed
            "kill-shard:1@q9",       // unknown trigger kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted bad spec {bad:?}");
        }
    }

    #[test]
    fn take_due_fires_in_spec_order_and_retains_the_rest() {
        let mut plan =
            FaultPlan::parse("restart-shard:1@b10,kill-shard:1@b2,kill-worker:0@t0.25").unwrap();
        assert!(plan.take_due(1, 0.0).is_empty());
        let due = plan.take_due(5, 0.3);
        assert_eq!(due.len(), 2);
        // Spec order among simultaneously due events, not trigger order.
        assert_eq!(due[0].action, FaultAction::KillShard(1));
        assert_eq!(due[1].action, FaultAction::KillWorker(0));
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.next_elapsed_trigger(), None);
        let rest = plan.take_due(10, 0.3);
        assert_eq!(rest.len(), 1);
        assert!(plan.is_empty());
    }

    #[test]
    fn next_elapsed_trigger_reports_the_earliest_pending_time() {
        let plan = FaultPlan::parse("kill-worker:0@t2,kill-shard:1@t0.5,restart-shard:1@b9")
            .unwrap();
        assert_eq!(plan.next_elapsed_trigger(), Some(0.5));
    }
}
