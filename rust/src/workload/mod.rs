//! Workload generation: sparse-ID streams (uniform / Zipf / production-
//! trace-like, Fig 14), Poisson request arrivals, query types for the
//! serving coordinator, and the multi-tenant traffic mix (per-query
//! model identity drawn from the Fig-1 fleet shares).

mod arrivals;
mod faults;
mod query;
mod sparse_gen;
mod traffic_mix;

pub use arrivals::{PoissonArrivals, RatePlan, ScheduledArrivals};
pub use faults::{FaultAction, FaultEvent, FaultPlan, FaultTrigger};
pub use query::{Query, QueryResult};
pub use sparse_gen::{unique_fraction, IdDistribution, SparseIdGen};
pub use traffic_mix::{QueryStream, TenantSpec, TrafficMix};
