//! Workload generation: sparse-ID streams (uniform / Zipf / production-
//! trace-like, Fig 14), Poisson request arrivals, and query types for the
//! serving coordinator.

mod arrivals;
mod query;
mod sparse_gen;

pub use arrivals::PoissonArrivals;
pub use query::{Query, QueryResult};
pub use sparse_gen::{unique_fraction, IdDistribution, SparseIdGen};
