//! Query types flowing through the serving coordinator. One query ranks
//! `items` candidate posts for one user (paper §II: requests are batched
//! so many user-post pairs are scored at once).


/// A ranking request: score `items` candidates with model `model`.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u64,
    pub model: String,
    /// Number of user-post pairs to score (the batch contribution).
    pub items: usize,
    /// Arrival timestamp, seconds since run start.
    pub arrival_s: f64,
    /// Seed for this query's sparse-feature generation.
    pub seed: u64,
    /// Server-assigned completion-handle id, unique per submission.
    /// Caller-supplied `id`s are free to collide across client threads;
    /// `ServerHandle::submit` stamps this so results always route back
    /// to the right ticket. 0 until submitted.
    pub ticket: u64,
}

impl Query {
    pub fn new(id: u64, model: impl Into<String>, items: usize, arrival_s: f64) -> Self {
        let model = model.into();
        Query { id, seed: id.wrapping_mul(0x9E3779B97F4A7C15), model, items, arrival_s, ticket: 0 }
    }
}

/// Completion record produced by a worker.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub id: u64,
    /// Ticket id copied from the query (see `Query::ticket`).
    pub ticket: u64,
    pub model: String,
    pub items: usize,
    /// Predicted CTRs (PJRT backend) or empty (simulation backend).
    pub ctrs: Vec<f32>,
    pub latency_ms: f64,
    /// Which batch bucket the query was executed in.
    pub batch_bucket: usize,
    /// Worker that executed it.
    pub worker: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_derived_from_id() {
        let a = Query::new(7, "rmc1-small", 4, 0.0);
        let b = Query::new(7, "rmc1-small", 4, 1.0);
        assert_eq!(a.seed, b.seed);
        assert_ne!(Query::new(8, "m", 1, 0.0).seed, a.seed);
    }
}
