//! Sparse-ID generators — the input side of the paper's locality story.
//!
//! Fig 14 shows the fraction of *unique* sparse IDs varies widely across
//! production use cases, which is what makes caching/prefetching viable.
//! We provide three generator families spanning that spectrum:
//!
//! * `Uniform` — worst case, every lookup ~unique (compulsory misses).
//! * `Zipf { s }` — power-law popularity, the standard model for user/
//!   item interaction frequency; higher `s` = hotter head = fewer uniques.
//! * `Trace { hot_fraction, hot_prob }` — a two-tier working-set model
//!   mimicking production embedding traces (a small hot set absorbs most
//!   lookups; the tail churns).

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IdDistribution {
    Uniform,
    Zipf { s: f64 },
    Trace { hot_fraction: f64, hot_prob: f64 },
}

impl IdDistribution {
    pub fn name(&self) -> String {
        match self {
            IdDistribution::Uniform => "uniform".into(),
            IdDistribution::Zipf { s } => format!("zipf-{s}"),
            IdDistribution::Trace { hot_fraction, hot_prob } => {
                format!("trace-h{hot_fraction}-p{hot_prob}")
            }
        }
    }
}

/// Deterministic (seeded) sparse-ID stream over a `rows`-row table.
#[derive(Debug, Clone)]
pub struct SparseIdGen {
    pub dist: IdDistribution,
    pub rows: usize,
    rng: Rng,
    /// Precomputed Zipf inverse-CDF table (perf: one powf per sample was
    /// still ~31ns; the 1025-point interpolated table samples in ~5ns —
    /// see EXPERIMENTS.md §Perf). Monotone in u; interpolation error is
    /// immaterial for workload popularity modeling.
    zipf_table: Vec<f64>,
    /// Trace hot-set size, hoisted to construction: `next_id` used to
    /// recompute `(rows * hot_fraction) as u64` from floats on every
    /// sample. The value is a pure function of (rows, hot_fraction), so
    /// hoisting cannot change the stream (pinned by
    /// `trace_stream_golden_values`). Zero for non-Trace distributions.
    hot_rows: u64,
}

const ZIPF_TABLE: usize = 1024;

impl SparseIdGen {
    pub fn new(dist: IdDistribution, rows: usize, seed: u64) -> Self {
        assert!(rows > 0, "table must have rows");
        let mut zipf_table = Vec::new();
        if let IdDistribution::Zipf { s } = dist {
            assert!(s > 0.0, "zipf exponent must be positive");
            let n = rows as f64;
            zipf_table = (0..=ZIPF_TABLE)
                .map(|i| {
                    let u = i as f64 / ZIPF_TABLE as f64;
                    if (s - 1.0).abs() < 1e-9 {
                        n.powf(u)
                    } else {
                        let one_s = 1.0 - s;
                        (u * (n.powf(one_s) - 1.0) + 1.0).powf(1.0 / one_s)
                    }
                })
                .collect();
        }
        let hot_rows = match dist {
            IdDistribution::Trace { hot_fraction, .. } => {
                ((rows as f64 * hot_fraction) as u64).max(1)
            }
            _ => 0,
        };
        SparseIdGen { dist, rows, rng: Rng::seed_from_u64(seed), zipf_table, hot_rows }
    }

    /// The paper's default: production popularity is power-law; s ~= 1.05
    /// gives the hot-head reuse and unique-ID fractions the paper's
    /// Fig 14 band implies for ranking use cases.
    pub fn production_like(rows: usize, seed: u64) -> Self {
        Self::new(IdDistribution::Zipf { s: 1.05 }, rows, seed)
    }

    pub fn next_id(&mut self) -> u32 {
        match self.dist {
            IdDistribution::Uniform => self.rng.gen_range(self.rows as u64) as u32,
            IdDistribution::Zipf { .. } => {
                // Zipf ranks are 1-based; spread ranks over the table with
                // a multiplicative hash so hot rows are not contiguous
                // (production tables are not popularity-sorted).
                // Interpolated inverse-CDF (no powf on the hot path).
                let u = self.rng.gen_f64() * ZIPF_TABLE as f64;
                let i = (u as usize).min(ZIPF_TABLE - 1);
                let frac = u - i as f64;
                let x = self.zipf_table[i] * (1.0 - frac) + self.zipf_table[i + 1] * frac;
                let rank = (x as u64).clamp(1, self.rows as u64) - 1;
                // Multiply-shift range reduction (perf: u64 modulo was
                // ~25% of sampling cost).
                reduce(scatter(rank), self.rows) as u32
            }
            IdDistribution::Trace { hot_prob, .. } => {
                if self.rng.gen_bool(hot_prob) {
                    let r = self.rng.gen_range(self.hot_rows);
                    reduce(scatter(r), self.rows) as u32
                } else {
                    self.rng.gen_range(self.rows as u64) as u32
                }
            }
        }
    }

    /// One sample's lookup list (length = `lookups`).
    pub fn gen_lookups(&mut self, lookups: usize) -> Vec<u32> {
        (0..lookups).map(|_| self.next_id()).collect()
    }

    /// A full batch: `batch * lookups` IDs, row-major.
    pub fn gen_batch(&mut self, batch: usize, lookups: usize) -> Vec<u32> {
        (0..batch * lookups).map(|_| self.next_id()).collect()
    }
}

/// Multiply-shift reduction of a full-range u64 into [0, n).
#[inline]
fn reduce(x: u64, n: usize) -> u64 {
    ((x as u128 * n as u128) >> 64) as u64
}

/// Fixed multiplicative hash (splitmix-style) used to de-sort popularity.
fn scatter(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fraction of unique IDs in a window — Fig 14's y-axis.
pub fn unique_fraction(ids: &[u32]) -> f64 {
    if ids.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<u32> = ids.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len() as f64 / ids.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SparseIdGen::new(IdDistribution::Zipf { s: 0.9 }, 1000, 42);
        let mut b = SparseIdGen::new(IdDistribution::Zipf { s: 0.9 }, 1000, 42);
        assert_eq!(a.gen_lookups(64), b.gen_lookups(64));
    }

    #[test]
    fn ids_in_range() {
        for dist in [
            IdDistribution::Uniform,
            IdDistribution::Zipf { s: 1.1 },
            IdDistribution::Trace { hot_fraction: 0.01, hot_prob: 0.9 },
        ] {
            let mut g = SparseIdGen::new(dist, 37, 7);
            for id in g.gen_batch(16, 10) {
                assert!((id as usize) < 37);
            }
        }
    }

    #[test]
    fn zipf_has_fewer_uniques_than_uniform() {
        let rows = 100_000;
        let n = 20_000;
        let mut uni = SparseIdGen::new(IdDistribution::Uniform, rows, 1);
        let mut zip = SparseIdGen::new(IdDistribution::Zipf { s: 1.1 }, rows, 1);
        let u = unique_fraction(&uni.gen_batch(1, n));
        let z = unique_fraction(&zip.gen_batch(1, n));
        assert!(z < u, "zipf {z} should be < uniform {u}");
        assert!(z < 0.5);
    }

    #[test]
    fn hotter_trace_means_fewer_uniques() {
        let rows = 1_000_000;
        let mk = |p| {
            let mut g = SparseIdGen::new(
                IdDistribution::Trace { hot_fraction: 0.001, hot_prob: p },
                rows,
                3,
            );
            unique_fraction(&g.gen_batch(1, 50_000))
        };
        assert!(mk(0.95) < mk(0.5));
    }

    #[test]
    fn unique_fraction_edges() {
        assert_eq!(unique_fraction(&[]), 0.0);
        assert_eq!(unique_fraction(&[1, 1, 1, 1]), 0.25);
        assert_eq!(unique_fraction(&[1, 2, 3, 4]), 1.0);
    }

    #[test]
    fn trace_stream_golden_values() {
        // Regression pin for the hot_rows hoist: the first samples of
        // every distribution arm must stay bit-for-bit what they were
        // when hot_rows was recomputed per sample (values captured from
        // the pre-hoist implementation; the Trace arms are the ones the
        // hoist touches, the others pin the shared Rng plumbing).
        let rows = 1_000_000;
        let mut g = SparseIdGen::new(
            IdDistribution::Trace { hot_fraction: 0.001, hot_prob: 0.9 },
            rows,
            42,
        );
        assert_eq!(
            g.gen_lookups(12),
            [
                317431, 701135, 82212, 688479, 187157, 282332, 325468, 154098, 730590,
                121399, 786344, 678234
            ],
            "trace(0.001, 0.9) seed 42 stream drifted"
        );
        let mut g = SparseIdGen::new(
            IdDistribution::Trace { hot_fraction: 0.02, hot_prob: 0.5 },
            rows,
            7,
        );
        assert_eq!(
            g.gen_lookups(12),
            [
                850426, 427209, 465703, 329839, 73283, 348446, 113085, 72917, 766480,
                456175, 416650, 530866
            ],
            "trace(0.02, 0.5) seed 7 stream drifted"
        );
        // (No Zipf golden: its inverse-CDF table goes through powf,
        // whose last-ulp rounding is libm-specific — the hoist doesn't
        // touch that arm, and `deterministic_given_seed` already covers
        // its within-platform stability.)
        let mut g = SparseIdGen::new(IdDistribution::Uniform, rows, 42);
        assert_eq!(
            g.gen_lookups(12),
            [
                814305, 318821, 983894, 701135, 793504, 588098, 125352, 605122, 207717,
                933347, 559539, 850029
            ],
            "uniform seed 42 stream drifted"
        );
    }

    #[test]
    fn scatter_is_injective_enough() {
        use std::collections::HashSet;
        let set: HashSet<u64> = (0..10_000u64).map(scatter).collect();
        assert_eq!(set.len(), 10_000);
    }
}
