//! Sparse-ID generators — the input side of the paper's locality story.
//!
//! Fig 14 shows the fraction of *unique* sparse IDs varies widely across
//! production use cases, which is what makes caching/prefetching viable.
//! We provide three generator families spanning that spectrum:
//!
//! * `Uniform` — worst case, every lookup ~unique (compulsory misses).
//! * `Zipf { s }` — power-law popularity, the standard model for user/
//!   item interaction frequency; higher `s` = hotter head = fewer uniques.
//! * `Trace { hot_fraction, hot_prob }` — a two-tier working-set model
//!   mimicking production embedding traces (a small hot set absorbs most
//!   lookups; the tail churns).

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IdDistribution {
    Uniform,
    Zipf { s: f64 },
    Trace { hot_fraction: f64, hot_prob: f64 },
}

impl IdDistribution {
    pub fn name(&self) -> String {
        match self {
            IdDistribution::Uniform => "uniform".into(),
            IdDistribution::Zipf { s } => format!("zipf-{s}"),
            IdDistribution::Trace { hot_fraction, hot_prob } => {
                format!("trace-h{hot_fraction}-p{hot_prob}")
            }
        }
    }
}

/// Deterministic (seeded) sparse-ID stream over a `rows`-row table.
#[derive(Debug, Clone)]
pub struct SparseIdGen {
    pub dist: IdDistribution,
    pub rows: usize,
    rng: Rng,
    /// Precomputed Zipf inverse-CDF table in Q32 fixed point (rank x
    /// 2^32), 1025 points, monotone by construction. Two properties at
    /// once: perf (one powf per sample was ~31ns; table interpolation
    /// samples in ~5ns — see EXPERIMENTS.md §Perf) and bit-stability —
    /// the table is built with `detmath` (IEEE basic ops only, no libm
    /// powf) and sampled with pure integer arithmetic, so the Zipf
    /// stream is identical on every platform and golden-pinned like the
    /// other arms. Interpolation error is immaterial for workload
    /// popularity modeling.
    zipf_table: Vec<u64>,
    /// Trace hot-set size, hoisted to construction: `next_id` used to
    /// recompute `(rows * hot_fraction) as u64` from floats on every
    /// sample. The value is a pure function of (rows, hot_fraction), so
    /// hoisting cannot change the stream (pinned by
    /// `trace_stream_golden_values`). Zero for non-Trace distributions.
    hot_rows: u64,
}

const ZIPF_TABLE: usize = 1024;

impl SparseIdGen {
    pub fn new(dist: IdDistribution, rows: usize, seed: u64) -> Self {
        assert!(rows > 0, "table must have rows");
        let mut zipf_table = Vec::new();
        if let IdDistribution::Zipf { s } = dist {
            assert!(s > 0.0, "zipf exponent must be positive");
            assert!(rows <= u32::MAX as usize, "ids are u32");
            let n = rows as f64;
            zipf_table = (0..=ZIPF_TABLE)
                .map(|i| {
                    let u = i as f64 / ZIPF_TABLE as f64;
                    let x = if (s - 1.0).abs() < 1e-9 {
                        detmath::powf(n, u)
                    } else {
                        let one_s = 1.0 - s;
                        detmath::powf(u * (detmath::powf(n, one_s) - 1.0) + 1.0, 1.0 / one_s)
                    };
                    // Q32 fixed point; clamp to the rank range first so
                    // the scaling below cannot overflow.
                    (x.clamp(1.0, n) * 4294967296.0) as u64
                })
                .collect();
            // Monotone mathematically; enforce it bit-wise so the
            // integer interpolation in `next_id` can never wrap.
            for i in 1..zipf_table.len() {
                zipf_table[i] = zipf_table[i].max(zipf_table[i - 1]);
            }
        }
        let hot_rows = match dist {
            IdDistribution::Trace { hot_fraction, .. } => {
                ((rows as f64 * hot_fraction) as u64).max(1)
            }
            _ => 0,
        };
        SparseIdGen { dist, rows, rng: Rng::seed_from_u64(seed), zipf_table, hot_rows }
    }

    /// The paper's default: production popularity is power-law; s ~= 1.05
    /// gives the hot-head reuse and unique-ID fractions the paper's
    /// Fig 14 band implies for ranking use cases.
    pub fn production_like(rows: usize, seed: u64) -> Self {
        Self::new(IdDistribution::Zipf { s: 1.05 }, rows, seed)
    }

    pub fn next_id(&mut self) -> u32 {
        match self.dist {
            IdDistribution::Uniform => self.rng.gen_range(self.rows as u64) as u32,
            IdDistribution::Zipf { .. } => {
                // Zipf ranks are 1-based; spread ranks over the table with
                // a multiplicative hash so hot rows are not contiguous
                // (production tables are not popularity-sorted).
                // One integer draw resolves the sample: the top 10 bits
                // pick the inverse-CDF cell, the next 32 interpolate
                // inside it in Q32 — no float math on the hot path, so
                // the stream is bit-stable across platforms (pinned by
                // `trace_stream_golden_values`).
                let bits = self.rng.next_u64();
                let i = (bits >> 54) as usize;
                let frac = (bits >> 22) & 0xFFFF_FFFF;
                let lo = self.zipf_table[i];
                let hi = self.zipf_table[i + 1];
                let x = lo + (((hi - lo) as u128 * frac as u128) >> 32) as u64;
                let rank = (x >> 32).clamp(1, self.rows as u64) - 1;
                // Multiply-shift range reduction (perf: u64 modulo was
                // ~25% of sampling cost).
                reduce(scatter(rank), self.rows) as u32
            }
            IdDistribution::Trace { hot_prob, .. } => {
                if self.rng.gen_bool(hot_prob) {
                    let r = self.rng.gen_range(self.hot_rows);
                    reduce(scatter(r), self.rows) as u32
                } else {
                    self.rng.gen_range(self.rows as u64) as u32
                }
            }
        }
    }

    /// One sample's lookup list (length = `lookups`).
    pub fn gen_lookups(&mut self, lookups: usize) -> Vec<u32> {
        (0..lookups).map(|_| self.next_id()).collect()
    }

    /// A full batch: `batch * lookups` IDs, row-major.
    pub fn gen_batch(&mut self, batch: usize, lookups: usize) -> Vec<u32> {
        (0..batch * lookups).map(|_| self.next_id()).collect()
    }
}

/// Bit-stable ln/exp/pow built from IEEE-754 basic operations only.
///
/// `+ - * /`, comparisons, casts, and bit-level exponent manipulation
/// are exactly specified by IEEE 754 / the Rust reference, so these
/// return the same bits on every conforming platform — unlike libm's
/// `powf`, whose last-ulp rounding varies by implementation (which is
/// why the Zipf stream historically could not be golden-pinned). Fixed
/// iteration counts keep the rounding sequence identical everywhere;
/// truncation error sits far below f64 resolution for our ranges.
mod detmath {
    /// ln(2) rounded to f64 (a fixed literal, not a libm product).
    const LN2: f64 = 0.693_147_180_559_945_3;
    const SQRT2: f64 = 1.414_213_562_373_095_1;

    /// Natural log for finite x > 0: exponent split + centered
    /// mantissa, then 2·atanh((m-1)/(m+1)) via a 16-term odd series
    /// (|t| <= 0.172 after centering, so the first dropped term is
    /// < 1e-26).
    pub fn ln(x: f64) -> f64 {
        debug_assert!(x > 0.0 && x.is_finite());
        let bits = x.to_bits();
        let mut e = ((bits >> 52) & 0x7FF) as i64 - 1023;
        let mut m = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | (1023u64 << 52));
        if m > SQRT2 {
            m *= 0.5;
            e += 1;
        }
        let t = (m - 1.0) / (m + 1.0);
        let t2 = t * t;
        let mut sum = 0.0;
        let mut term = t;
        for k in 0..16u32 {
            sum += term / (2 * k + 1) as f64;
            term *= t2;
        }
        e as f64 * LN2 + 2.0 * sum
    }

    /// exp(x) for moderate |x|: nearest-integer ln2 reduction, 20-term
    /// Taylor series on the remainder (|r| <= ~0.35, dropped term
    /// < 1e-27), exact power-of-two rescale via the exponent field.
    pub fn exp(x: f64) -> f64 {
        debug_assert!(x.is_finite() && x.abs() < 700.0);
        let y = x / LN2;
        let n = if y >= 0.0 { (y + 0.5) as i64 } else { (y - 0.5) as i64 };
        let r = x - n as f64 * LN2;
        let mut term = 1.0;
        let mut sum = 1.0;
        for k in 1..=20u32 {
            term = term * r / k as f64;
            sum += term;
        }
        sum * f64::from_bits(((1023 + n) as u64) << 52)
    }

    /// a^b for finite a > 0.
    pub fn powf(a: f64, b: f64) -> f64 {
        exp(b * ln(a))
    }
}

/// Multiply-shift reduction of a full-range u64 into [0, n).
#[inline]
fn reduce(x: u64, n: usize) -> u64 {
    ((x as u128 * n as u128) >> 64) as u64
}

/// Fixed multiplicative hash (splitmix-style) used to de-sort popularity.
fn scatter(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fraction of unique IDs in a window — Fig 14's y-axis.
pub fn unique_fraction(ids: &[u32]) -> f64 {
    if ids.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<u32> = ids.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len() as f64 / ids.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SparseIdGen::new(IdDistribution::Zipf { s: 0.9 }, 1000, 42);
        let mut b = SparseIdGen::new(IdDistribution::Zipf { s: 0.9 }, 1000, 42);
        assert_eq!(a.gen_lookups(64), b.gen_lookups(64));
    }

    #[test]
    fn ids_in_range() {
        for dist in [
            IdDistribution::Uniform,
            IdDistribution::Zipf { s: 1.1 },
            IdDistribution::Trace { hot_fraction: 0.01, hot_prob: 0.9 },
        ] {
            let mut g = SparseIdGen::new(dist, 37, 7);
            for id in g.gen_batch(16, 10) {
                assert!((id as usize) < 37);
            }
        }
    }

    #[test]
    fn zipf_has_fewer_uniques_than_uniform() {
        let rows = 100_000;
        let n = 20_000;
        let mut uni = SparseIdGen::new(IdDistribution::Uniform, rows, 1);
        let mut zip = SparseIdGen::new(IdDistribution::Zipf { s: 1.1 }, rows, 1);
        let u = unique_fraction(&uni.gen_batch(1, n));
        let z = unique_fraction(&zip.gen_batch(1, n));
        assert!(z < u, "zipf {z} should be < uniform {u}");
        assert!(z < 0.5);
    }

    #[test]
    fn hotter_trace_means_fewer_uniques() {
        let rows = 1_000_000;
        let mk = |p| {
            let mut g = SparseIdGen::new(
                IdDistribution::Trace { hot_fraction: 0.001, hot_prob: p },
                rows,
                3,
            );
            unique_fraction(&g.gen_batch(1, 50_000))
        };
        assert!(mk(0.95) < mk(0.5));
    }

    #[test]
    fn unique_fraction_edges() {
        assert_eq!(unique_fraction(&[]), 0.0);
        assert_eq!(unique_fraction(&[1, 1, 1, 1]), 0.25);
        assert_eq!(unique_fraction(&[1, 2, 3, 4]), 1.0);
    }

    #[test]
    fn trace_stream_golden_values() {
        // Regression pin for the hot_rows hoist: the first samples of
        // every distribution arm must stay bit-for-bit what they were
        // when hot_rows was recomputed per sample (values captured from
        // the pre-hoist implementation; the Trace arms are the ones the
        // hoist touches, the others pin the shared Rng plumbing).
        let rows = 1_000_000;
        let mut g = SparseIdGen::new(
            IdDistribution::Trace { hot_fraction: 0.001, hot_prob: 0.9 },
            rows,
            42,
        );
        assert_eq!(
            g.gen_lookups(12),
            [
                317431, 701135, 82212, 688479, 187157, 282332, 325468, 154098, 730590,
                121399, 786344, 678234
            ],
            "trace(0.001, 0.9) seed 42 stream drifted"
        );
        let mut g = SparseIdGen::new(
            IdDistribution::Trace { hot_fraction: 0.02, hot_prob: 0.5 },
            rows,
            7,
        );
        assert_eq!(
            g.gen_lookups(12),
            [
                850426, 427209, 465703, 329839, 73283, 348446, 113085, 72917, 766480,
                456175, 416650, 530866
            ],
            "trace(0.02, 0.5) seed 7 stream drifted"
        );
        // Zipf goldens, both exponent branches: the table is built with
        // detmath (IEEE basic ops only) and sampled with integer
        // interpolation, so — unlike the old libm-powf table — the
        // stream is pinnable across platforms. Values cross-computed
        // with an independent bit-exact mirror of detmath + the Rng.
        let mut g = SparseIdGen::new(IdDistribution::Zipf { s: 1.05 }, rows, 42);
        assert_eq!(
            g.gen_lookups(12),
            [
                498229, 659886, 212174, 951014, 372805, 436502, 591189, 395272, 389829,
                956152, 676979, 293278
            ],
            "zipf(1.05) seed 42 stream drifted"
        );
        let mut g = SparseIdGen::new(IdDistribution::Zipf { s: 1.0 }, rows, 7);
        assert_eq!(
            g.gen_lookups(12),
            [
                566561, 682362, 801371, 809468, 32767, 595627, 911825, 960313, 815072,
                566561, 113450, 682362
            ],
            "zipf(1.0) seed 7 stream drifted"
        );
        let mut g = SparseIdGen::new(IdDistribution::Uniform, rows, 42);
        assert_eq!(
            g.gen_lookups(12),
            [
                814305, 318821, 983894, 701135, 793504, 588098, 125352, 605122, 207717,
                933347, 559539, 850029
            ],
            "uniform seed 42 stream drifted"
        );
    }

    #[test]
    fn detmath_tracks_libm() {
        // The bit-stable series must agree with libm to well under the
        // interpolation error that dominates the Zipf table (~1e-3 in
        // rank space); in practice they agree to ~1 ulp.
        for x in [1e-6, 0.07, 0.5, 0.999, 1.0, 1.5, 2.0, 3.14159, 97.0, 1e6] {
            let (det, lib) = (detmath::ln(x), x.ln());
            assert!(
                (det - lib).abs() <= 1e-12 * (1.0 + lib.abs()),
                "ln({x}): {det} vs {lib}"
            );
        }
        for x in [-20.0, -1.5, -0.3, 0.0, 0.3, 1.0, 4.7, 13.8, 20.0] {
            let (det, lib) = (detmath::exp(x), x.exp());
            assert!(
                ((det - lib) / lib).abs() <= 1e-12,
                "exp({x}): {det} vs {lib}"
            );
        }
        for (a, b) in [(1e6, 0.5), (1e6, -0.05), (2.0, 10.0), (1.000001, 3.0), (50.0, 1.0)] {
            let (det, lib) = (detmath::powf(a, b), a.powf(b));
            assert!(
                ((det - lib) / lib).abs() <= 1e-12,
                "powf({a}, {b}): {det} vs {lib}"
            );
        }
        assert_eq!(detmath::exp(0.0), 1.0);
        assert_eq!(detmath::ln(1.0), 0.0);
    }

    #[test]
    fn zipf_table_is_monotone_and_spans_ranks() {
        for s in [0.8, 1.0, 1.05, 1.3] {
            let g = SparseIdGen::new(IdDistribution::Zipf { s }, 1_000_000, 1);
            assert_eq!(g.zipf_table.len(), ZIPF_TABLE + 1);
            assert!(g.zipf_table.windows(2).all(|w| w[0] <= w[1]), "s={s} not monotone");
            assert_eq!(g.zipf_table[0] >> 32, 1, "s={s}: u=0 must map to rank 1");
            let top = g.zipf_table[ZIPF_TABLE] >> 32;
            assert!(
                (999_000..=1_000_000).contains(&top),
                "s={s}: u=1 maps to rank {top}, expected ~n"
            );
        }
    }

    #[test]
    fn scatter_is_injective_enough() {
        use std::collections::HashSet;
        let set: HashSet<u64> = (0..10_000u64).map(scatter).collect();
        assert_eq!(set.len(), 10_000);
    }
}
