//! Multi-tenant traffic generation: per-query model identity drawn from
//! the Fig-1 fleet shares, with per-tenant item-count distributions and
//! SLA targets. This is the workload half of the co-location experiment
//! (paper §VI): production machines never serve one model — they serve
//! the fleet mix, and the scheduler's job is to keep *every* tenant
//! inside its own latency bound.

use crate::fleet::{SHARE_RMC1, SHARE_RMC2, SHARE_RMC3};
use crate::util::Rng;

use super::{PoissonArrivals, Query, RatePlan, ScheduledArrivals};

/// One tenant (model class) in the served mix.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Full preset name (e.g. "rmc1-small").
    pub model: String,
    /// Fraction of queries belonging to this tenant (normalized so the
    /// mix sums to 1; the tenant's arrival rate is `share × total qps`).
    pub share: f64,
    /// Mean candidate items per query; drawn uniform in [1, 2·mean-1].
    pub items_mean: usize,
    /// Per-tenant latency bound, ms. `None` = the deployment default.
    pub sla_ms: Option<f64>,
}

/// A weighted tenant set plus the generator that interleaves their
/// Poisson arrivals into one open-loop query schedule.
#[derive(Debug, Clone)]
pub struct TrafficMix {
    pub tenants: Vec<TenantSpec>,
}

/// Default mean items per query for a model class: the filtering-stage
/// models (RMC1/2) score few candidates per request, the heavy ranking
/// model (RMC3) scores more (paper §III.A two-stage funnel).
fn default_items_mean(model: &str) -> usize {
    if model.starts_with("rmc3") {
        8
    } else {
        4
    }
}

/// Resolve a spec name against the model presets: exact preset name, or
/// a class shorthand ("rmc1" → "rmc1-small").
fn resolve_model(name: &str) -> anyhow::Result<String> {
    let presets = crate::config::all_rmc();
    if presets.iter().any(|c| c.name == name) {
        return Ok(name.to_string());
    }
    let small = format!("{name}-small");
    if presets.iter().any(|c| c.name == small) {
        return Ok(small);
    }
    anyhow::bail!(
        "unknown model '{name}' in mix (known: {:?})",
        presets.iter().map(|c| c.name.clone()).collect::<Vec<_>>()
    )
}

impl TrafficMix {
    /// Parse `model:share[,model:share]...` (e.g. the Fig-1 RMC split
    /// `rmc1:0.46,rmc2:0.31,rmc3:0.23`). An optional third field sets a
    /// per-tenant SLA in ms: `rmc1:0.46:20`. Shares are normalized;
    /// unknown models, non-positive shares, and duplicates are errors.
    pub fn parse(spec: &str) -> anyhow::Result<TrafficMix> {
        let mut tenants: Vec<TenantSpec> = Vec::new();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(
                fields.len() == 2 || fields.len() == 3,
                "bad mix entry '{part}' (expected model:share or model:share:sla_ms)"
            );
            let model = resolve_model(fields[0].trim())?;
            let share: f64 = fields[1]
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad share '{}' in '{part}'", fields[1]))?;
            anyhow::ensure!(share > 0.0 && share.is_finite(), "share must be > 0 in '{part}'");
            let sla_ms = match fields.get(2) {
                Some(s) => {
                    let v: f64 = s
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad sla '{s}' in '{part}'"))?;
                    anyhow::ensure!(v > 0.0, "sla must be > 0 in '{part}'");
                    Some(v)
                }
                None => None,
            };
            anyhow::ensure!(
                !tenants.iter().any(|t| t.model == model),
                "duplicate tenant '{model}' in mix"
            );
            tenants.push(TenantSpec {
                items_mean: default_items_mean(&model),
                model,
                share,
                sla_ms,
            });
        }
        anyhow::ensure!(!tenants.is_empty(), "empty traffic mix");
        let total: f64 = tenants.iter().map(|t| t.share).sum();
        for t in &mut tenants {
            t.share /= total;
        }
        Ok(TrafficMix { tenants })
    }

    /// The Fig-1 fleet mix restricted to the three RMC classes, with
    /// shares renormalized (0.30/0.20/0.15 → 0.46/0.31/0.23).
    pub fn fleet_default() -> TrafficMix {
        let total = SHARE_RMC1 + SHARE_RMC2 + SHARE_RMC3;
        let mk = |model: &str, share: f64| TenantSpec {
            model: model.into(),
            share: share / total,
            items_mean: default_items_mean(model),
            sla_ms: None,
        };
        TrafficMix {
            tenants: vec![
                mk("rmc1-small", SHARE_RMC1),
                mk("rmc2-small", SHARE_RMC2),
                mk("rmc3-small", SHARE_RMC3),
            ],
        }
    }

    /// A single-tenant mix (the pre-multi-tenant serving path).
    pub fn single(model: &str, items_mean: usize) -> TrafficMix {
        TrafficMix {
            tenants: vec![TenantSpec {
                model: model.into(),
                share: 1.0,
                items_mean,
                sla_ms: None,
            }],
        }
    }

    pub fn models(&self) -> Vec<String> {
        self.tenants.iter().map(|t| t.model.clone()).collect()
    }

    /// Generate `n` open-loop queries at aggregate rate `qps`: one
    /// merged Poisson arrival process, per-query tenant drawn from the
    /// mix shares, per-query items drawn from the tenant's distribution.
    /// Fully deterministic given `seed`. Materializes the whole
    /// schedule; prefer [`TrafficMix::stream`] for long runs.
    pub fn generate(&self, n: usize, qps: f64, seed: u64) -> Vec<Query> {
        self.stream(n, qps, seed).collect()
    }

    /// Streaming form of [`TrafficMix::generate`]: the same
    /// deterministic query sequence as a lazy iterator, so a
    /// multi-minute open-loop run holds O(1) queries in memory instead
    /// of the whole schedule (the server API paces straight off this).
    pub fn stream(&self, n: usize, qps: f64, seed: u64) -> QueryStream {
        QueryStream {
            mix: self.clone(),
            arr: ArrivalGen::Poisson(PoissonArrivals::new(qps, seed)),
            rng: Rng::seed_from_u64(seed ^ 0x7E41_A7C0_FFEE_D00D),
            next_id: 0,
            remaining: n,
        }
    }

    /// Like [`TrafficMix::stream`] but pacing arrivals against a
    /// time-varying [`RatePlan`] (diurnal ramps, flash crowds) instead
    /// of a flat Poisson rate. Tenant/item draws use the same RNG split
    /// as `stream`, so two sources with the same seed serve the same
    /// query identities — only the arrival times differ.
    pub fn stream_scheduled(&self, n: usize, plan: RatePlan, seed: u64) -> QueryStream {
        QueryStream {
            mix: self.clone(),
            arr: ArrivalGen::Scheduled(ScheduledArrivals::new(plan, seed)),
            rng: Rng::seed_from_u64(seed ^ 0x7E41_A7C0_FFEE_D00D),
            next_id: 0,
            remaining: n,
        }
    }

    fn draw_tenant(&self, rng: &mut Rng) -> &TenantSpec {
        let x = rng.gen_f64();
        let mut acc = 0.0;
        for t in &self.tenants {
            acc += t.share;
            if x < acc {
                return t;
            }
        }
        self.tenants.last().unwrap()
    }
}

/// Arrival pacing for a [`QueryStream`]: flat Poisson or a
/// piecewise-constant rate plan.
#[derive(Debug, Clone)]
enum ArrivalGen {
    Poisson(PoissonArrivals),
    Scheduled(ScheduledArrivals),
}

impl ArrivalGen {
    fn next_arrival_s(&mut self) -> f64 {
        match self {
            ArrivalGen::Poisson(p) => p.next_arrival_s(),
            ArrivalGen::Scheduled(s) => s.next_arrival_s(),
        }
    }
}

/// Lazy open-loop query source (see [`TrafficMix::stream`]). Owns its
/// RNG state, so two streams with the same (mix, n, qps, seed) yield
/// identical query sequences.
#[derive(Debug, Clone)]
pub struct QueryStream {
    mix: TrafficMix,
    arr: ArrivalGen,
    rng: Rng,
    next_id: u64,
    remaining: usize,
}

impl Iterator for QueryStream {
    type Item = Query;

    fn next(&mut self) -> Option<Query> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let t = self.mix.draw_tenant(&mut self.rng);
        // Uniform in [1, 2·mean-1] — mean items_mean, never 0.
        let span = (2 * t.items_mean).saturating_sub(1).max(1) as u64;
        let model = t.model.clone();
        let items = 1 + self.rng.gen_range(span) as usize;
        let id = self.next_id;
        self.next_id += 1;
        Some(Query::new(id, model, items, self.arr.next_arrival_s()))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for QueryStream {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_fig1_mix() {
        let mix = TrafficMix::parse("rmc1:0.46,rmc2:0.31,rmc3:0.23").unwrap();
        assert_eq!(mix.models(), vec!["rmc1-small", "rmc2-small", "rmc3-small"]);
        let total: f64 = mix.tenants.iter().map(|t| t.share).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!((mix.tenants[0].share - 0.46).abs() < 1e-9);
    }

    #[test]
    fn parse_normalizes_unnormalized_shares() {
        let mix = TrafficMix::parse("rmc1-small:3,rmc2-small:1").unwrap();
        assert!((mix.tenants[0].share - 0.75).abs() < 1e-12);
        assert!((mix.tenants[1].share - 0.25).abs() < 1e-12);
    }

    #[test]
    fn parse_per_tenant_sla() {
        let mix = TrafficMix::parse("rmc1:0.5:20,rmc3:0.5").unwrap();
        assert_eq!(mix.tenants[0].sla_ms, Some(20.0));
        assert_eq!(mix.tenants[1].sla_ms, None);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TrafficMix::parse("").is_err());
        assert!(TrafficMix::parse("nope:0.5").is_err());
        assert!(TrafficMix::parse("rmc1:0").is_err());
        assert!(TrafficMix::parse("rmc1:-1").is_err());
        assert!(TrafficMix::parse("rmc1:x").is_err());
        assert!(TrafficMix::parse("rmc1:0.5,rmc1:0.5").is_err());
        assert!(TrafficMix::parse("rmc1:0.5:0").is_err());
        assert!(TrafficMix::parse("rmc1").is_err());
    }

    #[test]
    fn fleet_default_matches_fig1_renormalization() {
        let mix = TrafficMix::fleet_default();
        assert_eq!(mix.tenants.len(), 3);
        assert!((mix.tenants[0].share - 0.30 / 0.65).abs() < 1e-12);
        let total: f64 = mix.tenants.iter().map(|t| t.share).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn generate_is_deterministic_and_share_accurate() {
        let mix = TrafficMix::parse("rmc1:0.46,rmc2:0.31,rmc3:0.23").unwrap();
        let a = mix.generate(4000, 1000.0, 7);
        let b = mix.generate(4000, 1000.0, 7);
        assert_eq!(a.len(), 4000);
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.model == y.model && x.items == y.items && x.arrival_s == y.arrival_s));
        // Arrivals are the merged Poisson process: strictly increasing.
        assert!(a.windows(2).all(|w| w[1].arrival_s > w[0].arrival_s));
        // Empirical shares track the configured ones.
        for t in &mix.tenants {
            let got =
                a.iter().filter(|q| q.model == t.model).count() as f64 / a.len() as f64;
            assert!((got - t.share).abs() < 0.04, "{}: got {got}, want {}", t.model, t.share);
        }
    }

    #[test]
    fn stream_matches_generate_lazily() {
        let mix = TrafficMix::parse("rmc1:0.5,rmc3:0.5").unwrap();
        let eager = mix.generate(500, 800.0, 13);
        let stream = mix.stream(500, 800.0, 13);
        assert_eq!(stream.len(), 500);
        let lazy: Vec<Query> = stream.collect();
        assert_eq!(eager.len(), lazy.len());
        assert!(eager.iter().zip(&lazy).all(|(a, b)| {
            a.id == b.id
                && a.model == b.model
                && a.items == b.items
                && a.arrival_s == b.arrival_s
                && a.seed == b.seed
        }));
    }

    #[test]
    fn stream_scheduled_keeps_query_identities() {
        // Same seed → same (model, items) sequence as the flat stream;
        // only arrival times change with the plan.
        let mix = TrafficMix::parse("rmc1:0.5,rmc3:0.5").unwrap();
        let flat: Vec<Query> = mix.stream(300, 500.0, 21).collect();
        let plan = RatePlan::flash_crowd(500.0, 2000.0, 0.2, 0.1);
        let shaped: Vec<Query> = mix.stream_scheduled(300, plan, 21).collect();
        assert_eq!(shaped.len(), 300);
        assert!(flat
            .iter()
            .zip(&shaped)
            .all(|(a, b)| a.id == b.id && a.model == b.model && a.items == b.items));
        assert!(shaped.windows(2).all(|w| w[1].arrival_s > w[0].arrival_s));
        // Determinism of the shaped source itself.
        let plan2 = RatePlan::flash_crowd(500.0, 2000.0, 0.2, 0.1);
        let again: Vec<Query> = mix.stream_scheduled(300, plan2, 21).collect();
        assert!(shaped.iter().zip(&again).all(|(a, b)| a.arrival_s == b.arrival_s));
    }

    #[test]
    fn generate_item_counts_track_tenant_means() {
        let mix = TrafficMix::parse("rmc1:0.5,rmc3:0.5").unwrap();
        let qs = mix.generate(4000, 1000.0, 3);
        let mean = |model: &str| {
            let v: Vec<usize> =
                qs.iter().filter(|q| q.model == model).map(|q| q.items).collect();
            v.iter().sum::<usize>() as f64 / v.len() as f64
        };
        assert!((mean("rmc1-small") - 4.0).abs() < 0.5);
        assert!((mean("rmc3-small") - 8.0).abs() < 1.0);
        assert!(qs.iter().all(|q| q.items >= 1));
    }
}
