//! Integration tests over the serving coordinator: end-to-end PJRT
//! serving, SLA accounting, and the heterogeneity-routing ablation on
//! the simulated fleet (the paper's scheduling insight).

use std::sync::Arc;
use std::time::Duration;

use recsys::config::{DeploymentConfig, ServerGen, ServerPoolConfig};
use recsys::coordinator::{Backend, Coordinator, MockBackend, SimBackend};
use recsys::workload::{PoissonArrivals, Query};

fn queries(n: usize, model: &str, items: usize, qps: f64, seed: u64) -> Vec<Query> {
    let mut arr = PoissonArrivals::new(qps, seed);
    (0..n)
        .map(|i| Query::new(i as u64, model, items, arr.next_arrival_s()))
        .collect()
}

fn deployment(pools: Vec<(ServerGen, usize)>, routing: &str, sla_ms: f64) -> DeploymentConfig {
    DeploymentConfig {
        sla_ms,
        batch_timeout_us: 300,
        max_batch: 128,
        routing: routing.into(),
        pools: pools
            .into_iter()
            .map(|(gen, machines)| ServerPoolConfig {
                gen,
                machines,
                colocation: 1,
                models: vec![],
            })
            .collect(),
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_serving_end_to_end() {
    use recsys::coordinator::PjrtBackend;
    use recsys::runtime::{default_artifacts_dir, ModelPool};
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let pool = Arc::new(ModelPool::new(&dir).unwrap());
    pool.preload("rmc1-small", "xla").unwrap();
    let buckets = pool.manifest.batches.clone();
    let backend = Arc::new(PjrtBackend::new(pool));
    let cfg = deployment(vec![(ServerGen::Broadwell, 2)], "least-loaded", 50.0);
    let mut c = Coordinator::new(&cfg, backend, buckets).unwrap();
    let report = c.run_open_loop(queries(120, "rmc1-small", 4, 300.0, 7), 50.0);
    assert_eq!(report.queries, 120);
    assert!(report.bounded_throughput > 0.0);
    assert!(
        report.violation_rate < 0.35,
        "too many SLA violations: {}",
        report.violation_rate
    );
    // CTR results flow back: batching actually happened.
    assert!(!report.bucket_histogram.is_empty());
    c.shutdown();
}

#[test]
fn heterogeneity_routing_beats_roundrobin_on_mixed_fleet() {
    // The paper's Takeaway 3/4 scheduling insight, as an ablation: on a
    // Broadwell+Skylake fleet serving batched traffic, batch-size-aware
    // routing should not lose to round-robin on latency-bounded
    // throughput. SimBackend sleeps the simulator-predicted latency of
    // the modeled Intel servers.
    let backend = Arc::new(SimBackend::new(1.0));
    // Pre-warm the latency cache so worker timing is steady.
    for gen in [ServerGen::Broadwell, ServerGen::Skylake] {
        backend.latency_ms("rmc1-small", 128, gen).unwrap();
        backend.latency_ms("rmc1-small", 8, gen).unwrap();
        backend.latency_ms("rmc1-small", 32, gen).unwrap();
        backend.latency_ms("rmc1-small", 1, gen).unwrap();
    }
    let run = |routing: &str, seed: u64| {
        let cfg = deployment(
            vec![(ServerGen::Broadwell, 1), (ServerGen::Skylake, 1)],
            routing,
            20.0,
        );
        let mut c = Coordinator::new(&cfg, backend.clone(), vec![1, 8, 32, 128]).unwrap();
        // Mixed load: many large queries (batched) at moderate rate.
        let report = c.run_open_loop(queries(60, "rmc1-small", 32, 150.0, seed), 20.0);
        c.shutdown();
        report
    };
    let het: f64 = (0..2).map(|s| run("heterogeneity", s).bounded_throughput).sum();
    let rr: f64 = (0..2).map(|s| run("round-robin", s).bounded_throughput).sum();
    assert!(
        het >= 0.8 * rr,
        "heterogeneity {het} items/s should be competitive with round-robin {rr}"
    );
}

#[test]
fn mock_backend_counts_every_query_under_overload() {
    // Overload: queries arrive faster than the backend can serve. All
    // queries still complete (no drops in the coordinator), SLA
    // accounting marks the late ones.
    let cfg = deployment(vec![(ServerGen::Broadwell, 1)], "round-robin", 2.0);
    let backend = Arc::new(MockBackend { latency: Duration::from_millis(4) });
    let mut c = Coordinator::new(&cfg, backend, vec![1, 8]).unwrap();
    let report = c.run_open_loop(queries(80, "m", 8, 5000.0, 3), 2.0);
    assert_eq!(report.queries, 80, "no query may be lost");
    assert!(report.violation_rate > 0.3, "overload must violate SLA");
    c.shutdown();
}

#[test]
fn multi_model_traffic_batches_per_model() {
    struct RecordingBackend;
    impl Backend for RecordingBackend {
        fn execute(
            &self,
            model: &str,
            bucket: usize,
            queries: &[Query],
            _gen: ServerGen,
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            // A batch must never mix models.
            for q in queries {
                assert_eq!(q.model, model, "mixed-model batch!");
            }
            assert!(bucket >= queries.iter().map(|q| q.items).sum::<usize>().min(bucket));
            Ok(queries.iter().map(|_| vec![]).collect())
        }
    }
    let cfg = deployment(vec![(ServerGen::Broadwell, 2)], "least-loaded", 50.0);
    let mut c = Coordinator::new(&cfg, Arc::new(RecordingBackend), vec![1, 8, 32]).unwrap();
    let mut qs = Vec::new();
    let mut arr = PoissonArrivals::new(2000.0, 11);
    for i in 0..100u64 {
        let model = if i % 3 == 0 { "rmc2-small" } else { "rmc1-small" };
        qs.push(Query::new(i, model, 3, arr.next_arrival_s()));
    }
    let report = c.run_open_loop(qs, 50.0);
    assert_eq!(report.queries, 100);
    c.shutdown();
}

#[test]
fn sim_backend_latencies_follow_paper_ordering() {
    // SimBackend exposes the modeled-machine latency table the router
    // exploits: Broadwell <= Skylake at small batch; Skylake wins at 128.
    let backend = SimBackend::new(0.0);
    let bdw_small = backend.latency_ms("rmc3-small", 8, ServerGen::Broadwell).unwrap();
    let skl_small = backend.latency_ms("rmc3-small", 8, ServerGen::Skylake).unwrap();
    let bdw_big = backend.latency_ms("rmc3-small", 128, ServerGen::Broadwell).unwrap();
    let skl_big = backend.latency_ms("rmc3-small", 128, ServerGen::Skylake).unwrap();
    assert!(bdw_small < skl_small);
    assert!(skl_big < bdw_big);
}
