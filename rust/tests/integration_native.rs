//! Integration tests over the native (pure-Rust) execution backend:
//! end-to-end serving through the full coordinator stack — router →
//! dynamic batcher → workers executing the real DLRM forward pass — with
//! no AOT artifacts and no XLA toolchain. This is the tier-1 E2E path a
//! fresh clone exercises.

use std::sync::Arc;

use recsys::config::{DeploymentConfig, ServerGen, ServerPoolConfig, PJRT_BATCHES};
use recsys::coordinator::{Coordinator, NativeBackend};
use recsys::runtime::{EngineKind, ExecOptions, NativeModel, NativePool};
use recsys::workload::{PoissonArrivals, Query, TrafficMix};

fn deployment(workers: usize, routing: &str, sla_ms: f64) -> DeploymentConfig {
    DeploymentConfig {
        sla_ms,
        batch_timeout_us: 300,
        max_batch: 128,
        routing: routing.into(),
        pools: vec![ServerPoolConfig {
            gen: ServerGen::Broadwell,
            machines: workers,
            colocation: 1,
            models: vec![],
        }],
    }
}

fn queries(n: usize, model: &str, items: usize, qps: f64, seed: u64) -> Vec<Query> {
    let mut arr = PoissonArrivals::new(qps, seed);
    (0..n)
        .map(|i| Query::new(i as u64, model, items, arr.next_arrival_s()))
        .collect()
}

#[test]
fn native_serving_end_to_end() {
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    let backend = Arc::new(NativeBackend::new(pool));
    let cfg = deployment(2, "least-loaded", 50.0);
    let mut c = Coordinator::new(&cfg, backend, PJRT_BATCHES.to_vec()).unwrap();
    let report = c.run_open_loop(queries(120, "rmc1-small", 4, 300.0, 7), 50.0);
    assert_eq!(report.queries, 120, "every query must complete");
    assert!(report.bounded_throughput > 0.0);
    assert!(
        report.violation_rate < 0.35,
        "too many SLA violations: {}",
        report.violation_rate
    );
    assert!(!report.bucket_histogram.is_empty(), "batching must have happened");
    c.shutdown();
}

#[test]
fn native_serving_multi_model() {
    // Two models through one fleet: per-model batching with lazily-built
    // native models (rmc1 is preloaded, rmc3 builds on first request).
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    let backend = Arc::new(NativeBackend::new(pool.clone()));
    let cfg = deployment(2, "round-robin", 200.0);
    let mut c = Coordinator::new(&cfg, backend, PJRT_BATCHES.to_vec()).unwrap();
    let mut arr = PoissonArrivals::new(400.0, 11);
    let qs: Vec<Query> = (0..60u64)
        .map(|i| {
            let model = if i % 3 == 0 { "rmc3-small" } else { "rmc1-small" };
            Query::new(i, model, 2, arr.next_arrival_s())
        })
        .collect();
    let report = c.run_open_loop(qs, 200.0);
    assert_eq!(report.queries, 60);
    c.shutdown();
    assert_eq!(pool.built_count(), 2, "one native model per preset");
}

#[test]
fn native_serving_never_fails_a_batch() {
    // Two identical runs through one worker under burst load: every
    // query executes successfully (a failed batch surfaces as an
    // infinite-latency marker, which would make p99 infinite). Batch
    // invariance of the numerics themselves is proven in the unit tests.
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    let run = |seed: u64| {
        let backend = Arc::new(NativeBackend::new(pool.clone()));
        let cfg = deployment(1, "round-robin", 100.0);
        let mut c = Coordinator::new(&cfg, backend, PJRT_BATCHES.to_vec()).unwrap();
        let report = c.run_open_loop(queries(30, "rmc1-small", 1, 5000.0, seed), 100.0);
        c.shutdown();
        report
    };
    let a = run(3);
    let b = run(3);
    assert_eq!(a.queries, 30);
    assert_eq!(b.queries, 30);
    // Deterministic inputs => both runs served every query successfully
    // (infinite-latency markers would show up as violations at 100% —
    // latency itself is wall-clock and may differ).
    assert!(a.p99_ms.is_finite() && b.p99_ms.is_finite());
}

#[test]
fn native_serving_with_intra_op_parallelism() {
    // Inter-query (2 coordinator workers) and intra-op (2 engine
    // threads) parallelism compose; every query completes and the run
    // stays SLA-healthy. Numeric equivalence of parallel execution is
    // proven bitwise in prop_invariants; this exercises the full stack.
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    let backend = Arc::new(NativeBackend::with_options(
        pool,
        ExecOptions { threads: 2, ..Default::default() },
    ));
    let cfg = deployment(2, "least-loaded", 100.0);
    let mut c = Coordinator::new(&cfg, backend, PJRT_BATCHES.to_vec()).unwrap();
    let report = c.run_open_loop(queries(80, "rmc1-small", 4, 400.0, 9), 100.0);
    assert_eq!(report.queries, 80, "every query must complete");
    assert!(report.p99_ms.is_finite(), "no batch may fail under the parallel engine");
    c.shutdown();
}

#[test]
fn native_serving_reference_engine_still_serves() {
    // The baseline kernels stay callable behind the engine flag (the
    // speedup in BENCH_runtime_hotpath.json is measured, not asserted).
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    let backend = Arc::new(NativeBackend::with_options(
        pool,
        ExecOptions { threads: 1, engine: EngineKind::Reference, ..Default::default() },
    ));
    let cfg = deployment(1, "round-robin", 200.0);
    let mut c = Coordinator::new(&cfg, backend, PJRT_BATCHES.to_vec()).unwrap();
    let report = c.run_open_loop(queries(30, "rmc1-small", 2, 300.0, 4), 200.0);
    assert_eq!(report.queries, 30);
    assert!(report.p99_ms.is_finite());
    c.shutdown();
}

#[test]
fn multi_tenant_colocated_serving_end_to_end() {
    // Two tenants co-located on one shared pool + engine: both complete,
    // both stay inside a loose SLA, and the report carries a per-tenant
    // breakdown whose slices sum to the aggregate.
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    pool.preload("rmc2-small").unwrap();
    let backend = Arc::new(NativeBackend::new(pool));
    let cfg = deployment(2, "least-loaded", 150.0);
    let mix = TrafficMix::parse("rmc1-small:0.6,rmc2-small:0.4").unwrap();
    let mut c = Coordinator::new_with_mix(&cfg, backend, PJRT_BATCHES.to_vec(), &mix).unwrap();
    let report = c.run_open_loop(mix.generate(100, 250.0, 21), 150.0);
    c.shutdown();
    assert_eq!(report.queries, 100, "every query must complete");
    assert!(!report.incomplete);
    assert_eq!(report.items, report.items_offered, "completed items == offered items");
    assert_eq!(report.per_tenant.len(), 2, "one slice per tenant");
    let mut tenant_queries = 0u64;
    let mut tenant_items = 0u64;
    for t in &report.per_tenant {
        assert!(t.queries >= 10, "{}: starved ({} queries)", t.model, t.queries);
        assert!(t.violation_rate < 0.35, "{}: violations {}", t.model, t.violation_rate);
        assert!(t.p99_ms.is_finite(), "{}: a batch failed", t.model);
        assert_eq!(t.sla_ms, 150.0);
        tenant_queries += t.queries;
        tenant_items += t.items;
    }
    assert_eq!(tenant_queries, report.queries, "tenant slices must cover the run");
    assert_eq!(tenant_items, report.items);
    let tenant_throughput: f64 =
        report.per_tenant.iter().map(|t| t.bounded_throughput).sum();
    assert!((report.bounded_throughput - tenant_throughput).abs() < 1e-6);
}

#[test]
fn multi_tenant_dedicated_partition_serving() {
    // `dedicated` routing share-partitions an unpinned pool and still
    // serves the whole mix: rmc1 gets 3 of 4 workers (share 0.75), rmc2
    // the rest, and both tenants' traffic completes on their partition.
    let pool = Arc::new(NativePool::new(0));
    pool.preload("rmc1-small").unwrap();
    pool.preload("rmc2-small").unwrap();
    let backend = Arc::new(NativeBackend::new(pool));
    let cfg = deployment(4, "dedicated", 150.0);
    let mix = TrafficMix::parse("rmc1-small:0.75,rmc2-small:0.25").unwrap();
    let mut c = Coordinator::new_with_mix(&cfg, backend, PJRT_BATCHES.to_vec(), &mix).unwrap();
    let parts = c.worker_models();
    assert_eq!(parts.iter().filter(|p| p == &&vec!["rmc1-small".to_string()]).count(), 3);
    assert_eq!(parts.iter().filter(|p| p == &&vec!["rmc2-small".to_string()]).count(), 1);
    let report = c.run_open_loop(mix.generate(80, 250.0, 33), 150.0);
    c.shutdown();
    assert_eq!(report.queries, 80);
    assert!(!report.incomplete);
    assert_eq!(report.per_tenant.len(), 2);
    for t in &report.per_tenant {
        assert!(t.p99_ms.is_finite(), "{}: a batch failed on its partition", t.model);
    }
}

#[test]
fn multi_tenant_sharded_backend_serving() {
    // ISSUE 4 satellite: a multi-tenant --mix through the *sharded*
    // backend — table-sharded SLS executors + leader hot-row cache —
    // composes with PR 3's co-location path. Every query completes on
    // the shared pool, per-tenant reports stay honest (slices cover the
    // run, completed == offered), and each tenant's service actually
    // served batches through shards and cache.
    let pool = Arc::new(NativePool::new(0));
    let backend = Arc::new(NativeBackend::with_options(
        pool,
        ExecOptions { shards: 2, cache_rows: 0.05, ..Default::default() },
    ));
    backend.preload("rmc1-small").unwrap();
    backend.preload("rmc3-small").unwrap();
    let cfg = deployment(2, "least-loaded", 200.0);
    let mix = TrafficMix::parse("rmc1-small:0.6,rmc3-small:0.4").unwrap();
    let mut c =
        Coordinator::new_with_mix(&cfg, backend.clone(), PJRT_BATCHES.to_vec(), &mix).unwrap();
    let report = c.run_open_loop(mix.generate(80, 250.0, 17), 200.0);
    c.shutdown();

    assert_eq!(report.queries, 80, "every query must complete through the sharded backend");
    assert!(!report.incomplete);
    assert_eq!(report.items, report.items_offered, "completion accounting must stay honest");
    assert_eq!(report.per_tenant.len(), 2, "one slice per tenant");
    let (mut tq, mut ti) = (0u64, 0u64);
    for t in &report.per_tenant {
        assert!(t.queries > 0, "{}: starved", t.model);
        assert!(t.p99_ms.is_finite(), "{}: a sharded batch failed", t.model);
        tq += t.queries;
        ti += t.items;
    }
    assert_eq!(tq, report.queries, "tenant slices must cover the run");
    assert_eq!(ti, report.items);

    let breakdown = backend.sharded_breakdown();
    assert_eq!(breakdown.len(), 2, "one sharded service per tenant model");
    for (model, s) in &breakdown {
        assert!(s.batches > 0, "{model}: service saw no batches");
        assert_eq!(s.shards, 2, "{model}: expected 2 shard executors");
        assert!(s.cache_capacity_rows > 0, "{model}: cache must be sized");
        assert!(
            s.cache_hits + s.cache_misses > 0,
            "{model}: cache must have seen lookup traffic"
        );
        assert!(s.gather_ns > 0.0 && s.leader_mlp_ns > 0.0, "{model}: empty breakdown");
    }
}

#[test]
fn quantized_serving_end_to_end() {
    // ISSUE 8 satellite: int8 rows through the full coordinator stack —
    // the sharded SLS executors and the leader hot-row cache hold
    // quantized bytes end-to-end. Every query completes, and the
    // sharded breakdown reports the serving dtype (no silent f32
    // fallback anywhere on the path).
    use recsys::runtime::TableDtype;
    let pool = Arc::new(NativePool::with_dtype(0, TableDtype::Int8));
    let backend = Arc::new(NativeBackend::with_options(
        pool,
        ExecOptions {
            shards: 2,
            cache_rows: 0.05,
            dtype: TableDtype::Int8,
            ..Default::default()
        },
    ));
    backend.preload("rmc1-small").unwrap();
    let cfg = deployment(2, "least-loaded", 200.0);
    let mut c = Coordinator::new(&cfg, backend.clone(), PJRT_BATCHES.to_vec()).unwrap();
    let report = c.run_open_loop(queries(60, "rmc1-small", 4, 300.0, 5), 200.0);
    c.shutdown();
    assert_eq!(report.queries, 60, "every query must complete on quantized tables");
    assert!(report.p99_ms.is_finite(), "no quantized batch may fail");
    let breakdown = backend.sharded_breakdown();
    assert_eq!(breakdown.len(), 1);
    let (model, s) = &breakdown[0];
    assert_eq!(s.dtype, "int8", "{model}: breakdown must carry the serving dtype");
    assert!(s.batches > 0 && s.shards == 2, "{model}: sharded service must have served");
}

#[test]
fn native_model_memory_footprint_is_scaled() {
    // The native path materializes pjrt_rows-scale tables: rmc2-small
    // must stay in the tens-of-MB band, not the paper's 10GB full scale.
    let m = NativeModel::from_name("rmc2-small", 0).unwrap();
    let mb = m.param_bytes() as f64 / 1e6;
    assert!(mb > 1.0 && mb < 200.0, "unexpected footprint: {mb} MB");
}
