//! Integration tests over the PJRT runtime: golden numerics end-to-end,
//! Pallas-vs-XLA executable cross-checks, and batching/padding
//! correctness. These require the `pjrt` cargo feature (the whole file
//! compiles to nothing without it) AND `make artifacts` to have run;
//! they skip (with a note) otherwise so `cargo test` stays runnable
//! from a fresh clone.

#![cfg(feature = "pjrt")]

use recsys::runtime::{
    default_artifacts_dir, golden_dense, golden_ids, golden_lwts, golden_ncf_ids, ModelPool,
};

fn pool() -> Option<ModelPool> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ModelPool::new(&dir).expect("pool"))
}

fn run_golden_rmc(pool: &ModelPool, model: &str, impl_: &str, batch: usize) -> (Vec<f32>, Vec<f32>) {
    let v = pool.manifest.find(model, impl_, batch).expect("variant");
    let golden = v.golden_ctr.clone().expect("golden batch");
    let t = v.config_usize("num_tables").unwrap();
    let l = v.config_usize("lookups").unwrap();
    let r = v.config_usize("rows").unwrap();
    let d = v.config_usize("dense_dim").unwrap();
    let compiled = pool.get(model, impl_, batch).expect("compile");
    let got = compiled
        .run_rmc(
            &golden_dense(batch, d),
            &golden_ids(t, batch, l, r),
            &golden_lwts(t, batch, l),
        )
        .expect("run");
    (got, golden)
}

#[test]
fn all_rmc_goldens_match_python() {
    let Some(pool) = pool() else { return };
    for model in ["rmc1-small", "rmc2-small", "rmc3-small"] {
        for batch in [1usize, 8] {
            let (got, want) = run_golden_rmc(&pool, model, "xla", batch);
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 2e-4,
                    "{model} b{batch} [{i}]: got {a}, python says {b}"
                );
            }
        }
    }
}

#[test]
fn pallas_executables_match_xla_executables() {
    // The L1 Pallas kernels and the pure-jnp graph must be numerically
    // interchangeable AFTER AOT compilation, not just under pytest.
    let Some(pool) = pool() else { return };
    for model in ["rmc1-small", "rmc2-small", "rmc3-small"] {
        let (pallas, _) = run_golden_rmc(&pool, model, "pallas", 1);
        let (xla, _) = run_golden_rmc(&pool, model, "xla", 1);
        for (a, b) in pallas.iter().zip(&xla) {
            assert!((a - b).abs() < 1e-4, "{model}: pallas {a} vs xla {b}");
        }
    }
}

#[test]
fn ncf_golden_matches_python() {
    let Some(pool) = pool() else { return };
    let v = pool.manifest.find("ncf", "xla", 8).expect("variant");
    let golden = v.golden_ctr.clone().unwrap();
    let users = v.config_usize("users").unwrap();
    let items = v.config_usize("items").unwrap();
    let (u, i) = golden_ncf_ids(8, users, items);
    let got = pool.get("ncf", "xla", 8).unwrap().run_ncf(&u, &i).unwrap();
    for (a, b) in got.iter().zip(&golden) {
        assert!((a - b).abs() < 2e-4, "ncf: {a} vs {b}");
    }
}

#[test]
fn padding_samples_do_not_change_real_outputs() {
    // Run the same sample through b1 and through b8-with-padding; the
    // real slot must agree. Padding uses lookup-weight 0.
    let Some(pool) = pool() else { return };
    let model = "rmc1-small";
    let v1 = pool.manifest.find(model, "xla", 1).unwrap();
    let t = v1.config_usize("num_tables").unwrap();
    let l = v1.config_usize("lookups").unwrap();
    let r = v1.config_usize("rows").unwrap();
    let d = v1.config_usize("dense_dim").unwrap();

    let dense1 = golden_dense(1, d);
    let ids1 = golden_ids(t, 1, l, r);
    let lwts1 = golden_lwts(t, 1, l);
    let out1 = pool.get(model, "xla", 1).unwrap().run_rmc(&dense1, &ids1, &lwts1).unwrap();

    // Build a b8 batch with the same sample in slot 0 and zero-weight
    // padding elsewhere (ids arbitrary).
    let b = 8;
    let mut dense8 = vec![0f32; b * d];
    dense8[..d].copy_from_slice(&dense1);
    let mut ids8 = vec![0i32; t * b * l];
    let mut lwts8 = vec![0f32; t * b * l];
    for table in 0..t {
        for j in 0..l {
            ids8[(table * b) * l + j] = ids1[table * l + j];
            lwts8[(table * b) * l + j] = 1.0;
        }
    }
    let out8 = pool.get(model, "xla", b).unwrap().run_rmc(&dense8, &ids8, &lwts8).unwrap();
    assert!(
        (out1[0] - out8[0]).abs() < 1e-5,
        "slot0 must be batch-invariant: {} vs {}",
        out1[0],
        out8[0]
    );
}

#[test]
fn outputs_depend_on_ids() {
    // Sanity: perturbing one sparse ID changes the CTR (the embedding
    // path is live, not dead-code-eliminated).
    let Some(pool) = pool() else { return };
    let model = "rmc2-small";
    let v = pool.manifest.find(model, "xla", 1).unwrap();
    let t = v.config_usize("num_tables").unwrap();
    let l = v.config_usize("lookups").unwrap();
    let r = v.config_usize("rows").unwrap();
    let d = v.config_usize("dense_dim").unwrap();
    let compiled = pool.get(model, "xla", 1).unwrap();
    let dense = golden_dense(1, d);
    let mut ids = golden_ids(t, 1, l, r);
    let lwts = golden_lwts(t, 1, l);
    let a = compiled.run_rmc(&dense, &ids, &lwts).unwrap()[0];
    ids[0] = (ids[0] + 1) % r as i32;
    let b = compiled.run_rmc(&dense, &ids, &lwts).unwrap()[0];
    assert_ne!(a, b, "CTR must react to sparse IDs");
    assert!(a > 0.0 && a < 1.0 && b > 0.0 && b < 1.0);
}

#[test]
fn wrong_input_sizes_rejected() {
    let Some(pool) = pool() else { return };
    let compiled = pool.get("rmc1-small", "xla", 1).unwrap();
    let err = compiled.run_rmc(&[0.0; 3], &[0; 3], &[0.0; 3]);
    assert!(err.is_err(), "short inputs must be rejected before PJRT");
}

#[test]
fn bucket_for_covers_serving_range() {
    let Some(pool) = pool() else { return };
    for n in 1..=200 {
        let bucket = pool.manifest.bucket_for("rmc1-small", "xla", n).unwrap();
        assert!(bucket >= n.min(128), "n={n} bucket={bucket}");
    }
}
