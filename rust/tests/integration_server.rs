//! Integration tests over the live serving API (ISSUE 5): concurrent
//! multi-client sessions, bounded admission control with explicit shed
//! accounting, drain-deadline honesty, and conformance of the open-loop
//! harness (now a thin client of the same API) with its historical
//! accounting.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use recsys::coordinator::{Coordinator, MockBackend, ServerBuilder, Ticket, TicketOutcome};
use recsys::runtime::ExecOptions;
use recsys::workload::{FaultPlan, PoissonArrivals, Query, TrafficMix};

/// The query set both multi-client determinism runs submit: two tenants,
/// ids 0..n (ids are the determinism key — CTRs derive from id seeds).
fn session_queries(n: usize) -> Vec<Query> {
    (0..n as u64)
        .map(|i| {
            let model = if i % 3 == 0 { "rmc2-small" } else { "rmc1-small" };
            Query::new(i, model, 1 + (i % 4) as usize, 0.0)
        })
        .collect()
}

fn native_server(workers: usize) -> recsys::coordinator::Server {
    ServerBuilder::new()
        .mix(TrafficMix::parse("rmc1-small:0.7,rmc2-small:0.3").unwrap())
        .workers(workers)
        .routing("least-loaded")
        .sla_ms(500.0)
        .native(ExecOptions::default())
        .build()
        .unwrap()
}

/// Submit `queries` from `clients` concurrent session threads and wait
/// every ticket; returns id -> (tenant, ctrs).
fn run_clients(
    server: &recsys::coordinator::Server,
    queries: Vec<Query>,
    clients: usize,
) -> BTreeMap<u64, (String, Vec<f32>)> {
    let tickets: Vec<Ticket> = std::thread::scope(|s| {
        let joins: Vec<_> = queries
            .chunks(queries.len().div_ceil(clients))
            .map(|chunk| {
                let handle = server.handle();
                let chunk = chunk.to_vec();
                s.spawn(move || {
                    chunk.into_iter().map(|q| handle.submit_live(q)).collect::<Vec<Ticket>>()
                })
            })
            .collect();
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
    });
    tickets
        .into_iter()
        .map(|t| {
            let outcome = t.wait();
            let done = outcome.completed().expect("uncapped run completes everything");
            (done.id, (done.tenant.clone(), done.ctrs.clone()))
        })
        .collect()
}

#[test]
fn concurrent_multi_client_matches_single_client() {
    // The determinism contract across the session API: per-query CTRs
    // served to 4 concurrent client threads are bitwise-identical to a
    // single client submitting the same queries — batch composition is
    // scheduling, never numerics. Per-ticket results must also match the
    // per-tenant ServeReport accounting exactly.
    let n = 48;
    let single_server = native_server(2);
    let single = run_clients(&single_server, session_queries(n), 1);
    let single_report = single_server.shutdown().expect("report");

    let multi_server = native_server(2);
    let multi = run_clients(&multi_server, session_queries(n), 4);
    let multi_report = multi_server.shutdown().expect("report");

    assert_eq!(single.len(), n);
    assert_eq!(multi.len(), n);
    for (id, (tenant, ctrs)) in &single {
        let (m_tenant, m_ctrs) = &multi[id];
        assert_eq!(tenant, m_tenant, "query {id} routed to a different tenant");
        assert_eq!(ctrs, m_ctrs, "query {id}: multi-client CTRs diverge from single-client");
        assert!(!ctrs.is_empty());
    }

    // Per-ticket results == per-tenant report accounting, on both runs.
    for (report, results) in [(&single_report, &single), (&multi_report, &multi)] {
        assert_eq!(report.queries, n as u64);
        assert_eq!(report.queries_shed, 0);
        assert!(!report.incomplete);
        let mut by_tenant: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for (_, (tenant, ctrs)) in results.iter() {
            let e = by_tenant.entry(tenant.clone()).or_default();
            e.0 += 1;
            e.1 += ctrs.len() as u64;
        }
        assert_eq!(report.per_tenant.len(), by_tenant.len());
        for t in &report.per_tenant {
            let (q, items) = by_tenant[&t.model];
            assert_eq!(t.queries, q, "{}: ticket count != report", t.model);
            assert_eq!(t.items, items, "{}: ticket items != report", t.model);
        }
    }
}

#[test]
fn overload_sheds_bounded_and_accounted() {
    // Shed-under-overload property, across cap settings: inflight never
    // exceeds the cap, every offered query resolves to exactly one of
    // completed/shed, and per-tenant shed counts sum to the total.
    for cap in [1usize, 8] {
        let server = ServerBuilder::new()
            .mix(TrafficMix::parse("rmc1-small:0.5,rmc2-small:0.5").unwrap())
            .workers(2)
            .routing("least-loaded")
            .sla_ms(50.0)
            .buckets(vec![1, 8])
            .backend(Arc::new(MockBackend { latency: Duration::from_millis(10) }))
            .inflight_cap(cap)
            .build()
            .unwrap();
        let (clients, per_client) = (4usize, 75usize);
        let outcomes: Vec<TicketOutcome> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..clients)
                .map(|c| {
                    let handle = server.handle();
                    s.spawn(move || {
                        let tickets: Vec<Ticket> = (0..per_client)
                            .map(|i| {
                                let id = (c * per_client + i) as u64;
                                let model = if id % 2 == 0 { "rmc1-small" } else { "rmc2-small" };
                                handle.submit_live(Query::new(id, model, 2, 0.0))
                            })
                            .collect();
                        tickets.iter().map(Ticket::wait).collect::<Vec<_>>()
                    })
                })
                .collect();
            joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
        });
        let offered = (clients * per_client) as u64;
        let completed = outcomes.iter().filter(|o| o.completed().is_some()).count() as u64;
        let rejected = outcomes.iter().filter(|o| o.is_rejected()).count() as u64;
        assert_eq!(
            completed + rejected,
            offered,
            "cap {cap}: every query resolves to exactly one of completed/shed"
        );
        assert!(rejected > 0, "cap {cap}: a 300-query flood must shed");

        let handle = server.handle();
        assert!(handle.quiesce(Duration::from_secs(20)).unwrap(), "cap {cap}: drain");
        let report = server.shutdown().expect("report");
        assert_eq!(report.queries_offered, offered, "cap {cap}");
        assert_eq!(report.queries, completed, "cap {cap}");
        assert_eq!(report.queries_shed, rejected, "cap {cap}");
        assert_eq!(report.inflight_cap, Some(cap), "cap {cap}");
        assert!(
            report.peak_inflight <= cap as u64,
            "cap {cap}: peak inflight {} exceeds the cap",
            report.peak_inflight
        );
        assert!(!report.incomplete, "cap {cap}: shed load is not incompleteness");
        let tenant_shed: u64 = report.per_tenant.iter().map(|t| t.shed_queries).sum();
        assert_eq!(tenant_shed, report.queries_shed, "cap {cap}: per-tenant sheds sum");
        let tenant_shed_items: u64 = report.per_tenant.iter().map(|t| t.shed_items).sum();
        assert_eq!(tenant_shed_items, report.items_shed, "cap {cap}");
        assert_eq!(
            report.items + report.items_shed,
            report.items_offered,
            "cap {cap}: item accounting is exact when nothing fails"
        );
    }
}

#[test]
fn run_open_loop_is_a_client_of_the_session_api() {
    // Conformance: the reimplemented open-loop harness reports the same
    // completion accounting as a manual ticket-session client submitting
    // the identical schedule (latency stats differ — pacing is real
    // time — but counts may not).
    let mk = || {
        ServerBuilder::new()
            .mix(TrafficMix::parse("rmc1-small:0.7,rmc2-small:0.3").unwrap())
            .workers(2)
            .routing("least-loaded")
            .sla_ms(50.0)
            .buckets(vec![1, 8])
            .backend(Arc::new(MockBackend { latency: Duration::from_micros(300) }))
            .build()
            .unwrap()
    };
    let mix = TrafficMix::parse("rmc1-small:0.7,rmc2-small:0.3").unwrap();

    // Harness path: a streaming (non-materialized) schedule.
    let mut coordinator = Coordinator::from_server(mk());
    let harness = coordinator.run_open_loop(mix.stream(80, 2000.0, 7), 50.0);
    coordinator.shutdown();

    // Manual session path: same schedule, unpaced.
    let server = mk();
    let handle = server.handle();
    let tickets: Vec<Ticket> = mix.stream(80, 2000.0, 7).map(|q| handle.submit(q)).collect();
    for t in &tickets {
        assert!(t.wait().completed().is_some());
    }
    assert!(handle.quiesce(Duration::from_secs(10)).unwrap());
    let manual = handle.report().unwrap();
    let _ = server.shutdown();

    assert_eq!(harness.queries, 80);
    assert_eq!(harness.queries, manual.queries);
    assert_eq!(harness.queries_offered, manual.queries_offered);
    assert_eq!(harness.items, manual.items);
    assert_eq!(harness.items_offered, manual.items_offered);
    assert_eq!(harness.queries_shed, 0);
    assert!(!harness.incomplete && !manual.incomplete);
    assert_eq!(harness.per_tenant.len(), manual.per_tenant.len());
    for (h, m) in harness.per_tenant.iter().zip(&manual.per_tenant) {
        assert_eq!(h.model, m.model);
        assert_eq!(h.queries, m.queries);
        assert_eq!(h.items, m.items);
        assert_eq!(h.sla_ms, m.sla_ms);
    }
    // Batches happened on both paths and cover every query.
    let batches: u64 = harness.bucket_histogram.iter().map(|(_, n)| *n).sum();
    assert_eq!(batches, 80, "one histogram entry per completed query");
    assert!(harness.qps_offered > 0.0 && harness.qps_offered.is_finite());
}

#[test]
fn worker_kill_midrun_retries_and_stays_bitwise() {
    // Fault-injected serving (ISSUE 7): killing a worker mid-run must
    // not lose queries or change numerics. A 2-worker native server has
    // worker 0 killed (and respawned) after the third dispatched batch;
    // every in-flight/queued batch on the dead worker resolves as a
    // failure event, the supervisor re-dispatches those queries to the
    // surviving fleet, and every ticket still completes with CTRs
    // bitwise-identical to a fault-free run of the same query set —
    // batch composition (including retry singletons) is scheduling,
    // never numerics.
    let n = 48;
    let baseline_server = native_server(2);
    let baseline = run_clients(&baseline_server, session_queries(n), 1);
    let _ = baseline_server.shutdown();

    let faulted_server = ServerBuilder::new()
        .mix(TrafficMix::parse("rmc1-small:0.7,rmc2-small:0.3").unwrap())
        .workers(2)
        .routing("least-loaded")
        .sla_ms(500.0)
        .native(ExecOptions::default())
        .faults(FaultPlan::parse("kill-worker:0@b3,restart-worker:0@b3").unwrap())
        .build()
        .unwrap();
    let faulted = run_clients(&faulted_server, session_queries(n), 2);
    let report = faulted_server.shutdown().expect("report");

    assert_eq!(faulted.len(), n);
    for (id, (tenant, ctrs)) in &baseline {
        let (f_tenant, f_ctrs) = &faulted[id];
        assert_eq!(tenant, f_tenant, "query {id} routed to a different tenant under faults");
        assert_eq!(ctrs, f_ctrs, "query {id}: CTRs diverge from the fault-free run");
        assert!(!ctrs.is_empty());
    }

    assert_eq!(report.worker_deaths, 1, "the injected kill is counted");
    assert_eq!(report.worker_restarts, 1);
    assert!(
        report.queries_retried > 0,
        "the killed worker's queued batches must be re-dispatched, not silently absorbed"
    );
    assert_eq!(report.queries_failed, 0, "retries absorb the kill; nothing exhausts its budget");
    assert_eq!(report.queries, n as u64);
    assert_eq!(report.queries_shed, 0);
    assert_eq!(
        report.queries_offered,
        report.queries + report.queries_shed + report.queries_failed,
        "degraded accounting identity"
    );
    assert!(!report.incomplete, "a killed-and-respawned worker is not incompleteness");
    assert!(report.degraded_duration_s >= 0.0);
}

#[test]
fn drain_deadline_trips_honestly() {
    // A worker stuck on a slow batch: the configured drain deadline
    // bounds the wait, and the report says so instead of hanging or
    // crediting unserved work.
    let server = ServerBuilder::new()
        .workers(1)
        .sla_ms(50.0)
        .buckets(vec![1])
        .max_batch(8)
        .backend(Arc::new(MockBackend { latency: Duration::from_millis(900) }))
        .drain_deadline(Duration::from_millis(80))
        .build()
        .unwrap();
    let mut coordinator = Coordinator::from_server(server);
    let queries: Vec<Query> = (0..2).map(|i| Query::new(i, "rmc1-small", 1, 0.0)).collect();
    let report = coordinator.run_open_loop(queries, 50.0);
    assert!(report.incomplete, "drain gave up before the slow batches finished");
    assert!(report.drain_deadline_hit);
    assert!(report.queries < report.queries_offered);
    assert_eq!(report.queries_offered, 2);
    coordinator.shutdown();
}

#[test]
fn open_loop_pacing_still_paces() {
    // The busy-loop fix replaced the 50µs recv slices with real sleeps;
    // pacing itself must survive: a 100-query schedule at 1000 qps takes
    // at least the schedule horizon of wall time.
    let server = ServerBuilder::new()
        .workers(1)
        .sla_ms(50.0)
        .buckets(vec![1, 8])
        .backend(Arc::new(MockBackend { latency: Duration::from_micros(100) }))
        .build()
        .unwrap();
    let mut coordinator = Coordinator::from_server(server);
    let mut arr = PoissonArrivals::new(1000.0, 5);
    let queries: Vec<Query> = (0..100u64)
        .map(|i| Query::new(i, "rmc1-small", 2, arr.next_arrival_s()))
        .collect();
    let horizon = queries.last().unwrap().arrival_s;
    let t0 = std::time::Instant::now();
    let report = coordinator.run_open_loop(queries, 50.0);
    let elapsed = t0.elapsed().as_secs_f64();
    assert_eq!(report.queries, 100);
    assert!(
        elapsed >= horizon * 0.9,
        "pacing collapsed: {elapsed:.3}s wall for a {horizon:.3}s schedule"
    );
    assert!((report.qps_offered - 100.0 / horizon).abs() / (100.0 / horizon) < 0.01);
    coordinator.shutdown();
}

#[test]
fn autotune_serves_with_decision_log_and_exact_accounting() {
    // ISSUE 9 tentpole: the online controller runs inside the live
    // dispatcher. A multi-tenant run with small windows must produce a
    // per-tenant trajectory (seed entry + at least one windowed
    // decision for the busy tenants) while the shed/failed/completed
    // accounting identity stays exact.
    use recsys::coordinator::AutotuneCfg;
    let mix = TrafficMix::parse("rmc1-small:0.7,rmc2-small:0.3").unwrap();
    let server = ServerBuilder::new()
        .mix(mix.clone())
        .workers(2)
        .routing("least-loaded")
        .sla_ms(50.0)
        .buckets(vec![1, 8, 32])
        .max_batch(32)
        .backend(Arc::new(MockBackend { latency: Duration::from_micros(200) }))
        .autotune(AutotuneCfg { window_queries: 8, ..Default::default() })
        .build()
        .unwrap();
    let mut coordinator = Coordinator::from_server(server);
    let report = coordinator.run_open_loop(mix.generate(240, 3000.0, 77), 50.0);
    coordinator.shutdown();

    assert_eq!(report.queries, 240);
    assert_eq!(
        report.queries_offered,
        report.queries + report.queries_shed + report.queries_failed,
        "autotune must not break the accounting identity"
    );
    assert_eq!(report.autotune.len(), 2, "one trajectory per mix tenant");
    for t in &report.autotune {
        assert!(
            !t.decisions.is_empty(),
            "{}: decision log must at least carry the seed entry",
            t.model
        );
        assert_eq!(t.decisions[0].action, "seed");
        assert!(
            t.final_max_batch >= 1 && t.final_timeout_us >= 50,
            "{}: final config ({}, {}us) out of range",
            t.model,
            t.final_max_batch,
            t.final_timeout_us
        );
    }
    // 240 queries at a 0.7 share with window 8 → the majority tenant
    // closes many windows; the controller must actually have stepped.
    let rmc1 = report.autotune.iter().find(|t| t.model == "rmc1-small").unwrap();
    assert!(rmc1.windows >= 3, "rmc1 closed {} windows", rmc1.windows);
    assert!(rmc1.decisions.len() as u64 >= rmc1.windows, "one log entry per window + seed");

    // The decision log is replayable: every logged config is one of the
    // tuner's discrete grid points (bucket ladder x timeout ladder).
    for d in &rmc1.decisions {
        assert!([1usize, 8, 32].contains(&d.max_batch), "bucket {} off-grid", d.max_batch);
        assert!(d.timeout_us >= 50, "timeout {}us below floor", d.timeout_us);
    }
}
