//! Integration tests over the wire front-end (ISSUE 10): in-process vs
//! wire conformance (bitwise CTRs + identical per-tenant accounting),
//! malformed-input safety (typed 4xx, no panics, no leaked admission
//! slots, nothing counted as offered), keep-alive sessions, the quiesce
//! endpoint, and shed mapping to 429 across real sockets.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use recsys::coordinator::{MockBackend, ServerBuilder, Ticket, SERVE_REPORT_SCHEMA};
use recsys::net::loadgen;
use recsys::net::{LoadgenCfg, Pacing, WireCfg, WireConn, WireServer};
use recsys::runtime::ExecOptions;
use recsys::util::Json;
use recsys::workload::TrafficMix;

const MIX: &str = "rmc1-small:0.7,rmc2-small:0.3";

fn native_server() -> recsys::coordinator::Server {
    ServerBuilder::new()
        .mix(TrafficMix::parse(MIX).unwrap())
        .workers(2)
        .routing("least-loaded")
        .sla_ms(500.0)
        .native(ExecOptions::default())
        .build()
        .unwrap()
}

fn start_wire(server: &recsys::coordinator::Server, cfg: WireCfg) -> WireServer {
    WireServer::start(
        "127.0.0.1:0",
        server.handle(),
        server.models(),
        Duration::from_secs(20),
        cfg,
    )
    .unwrap()
}

#[test]
fn wire_conformance_bitwise_with_in_process() {
    // The tentpole contract: the same (mix, n, seed) driven in-process
    // and over the wire serves bitwise-identical CTRs per query id and
    // lands the same per-tenant accounting in the report. Pacing,
    // connection count, and batch composition are scheduling — never
    // numerics, never counts.
    let (n, seed) = (60usize, 7u64);
    let mix = TrafficMix::parse(MIX).unwrap();

    // In-process run: submit the stream through the session API.
    let in_server = native_server();
    let handle = in_server.handle();
    let tickets: Vec<Ticket> =
        mix.stream(n, 2000.0, seed).map(|q| handle.submit_live(q)).collect();
    let mut in_bits: BTreeMap<u64, (String, Vec<u32>)> = BTreeMap::new();
    for t in tickets {
        let out = t.wait();
        let done = out.completed().expect("uncapped run completes everything");
        let bits = done.ctrs.iter().map(|x| x.to_bits()).collect();
        in_bits.insert(done.id, (done.tenant.clone(), bits));
    }
    assert!(handle.quiesce(Duration::from_secs(20)).unwrap());
    let in_report = handle.report().unwrap();
    drop(in_server);

    // Wire run: fresh server, same stream paced by the load generator
    // over real sockets (4 keep-alive connections).
    let wire_server = native_server();
    let wire = start_wire(&wire_server, WireCfg::default());
    let mut cfg = LoadgenCfg::new(wire.local_addr().to_string());
    cfg.collect_ctrs = true;
    cfg.quiesce = true;
    let stats = loadgen::run(&mix, n, Pacing::Qps(2000.0), seed, &cfg).unwrap();

    assert_eq!(stats.completed, n as u64, "every wire query completes");
    assert_eq!(stats.transport_errors, 0);
    assert_eq!(stats.ctr_bits.len(), n);
    for (id, (tenant, bits)) in &in_bits {
        assert_eq!(
            stats.tenants.get(id),
            Some(tenant),
            "query {id}: wire run routed to a different tenant"
        );
        assert_eq!(
            stats.ctr_bits.get(id),
            Some(bits),
            "query {id}: wire CTR bits diverge from in-process"
        );
        assert!(!bits.is_empty());
    }

    // Same per-tenant accounting identity on both sides of the socket.
    let report = stats.report.as_ref().expect("quiesce returns the drained report");
    assert_eq!(
        report.get("schema").and_then(Json::as_str),
        Some(SERVE_REPORT_SCHEMA)
    );
    let (offered, completed, shed, failed, ok) = stats.report_identity().unwrap();
    assert!(ok, "wire identity violated: {offered} != {completed} + {shed} + {failed}");
    assert_eq!(offered, n as u64);
    assert_eq!(completed, in_report.queries);
    assert_eq!(shed, in_report.queries_shed);
    assert_eq!(failed, in_report.queries_failed);
    let wire_tenants = report.get("per_tenant").and_then(Json::as_arr).unwrap();
    assert_eq!(wire_tenants.len(), in_report.per_tenant.len());
    for (w, t) in wire_tenants.iter().zip(&in_report.per_tenant) {
        let f = |k: &str| w.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(w.get("model").and_then(Json::as_str), Some(t.model.as_str()));
        assert_eq!(f("queries") as u64, t.queries, "{}: wire tenant queries", t.model);
        assert_eq!(f("items") as u64, t.items, "{}: wire tenant items", t.model);
        assert_eq!(f("shed_queries") as u64, t.shed_queries, "{}", t.model);
    }
    assert_eq!(stats.drained, Some(true));
}

/// Write raw bytes, read everything until the server closes, return the
/// parsed status line. Framing-error paths always close the connection.
fn raw_roundtrip(addr: &str, req: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(req).unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf).into_owned();
    let status: u16 = text
        .split_ascii_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no status line in response: '{text}'"));
    (status, text)
}

fn raw_post_query(addr: &str, body: &[u8]) -> (u16, String) {
    let mut req = format!(
        "POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(body);
    raw_roundtrip(addr, &req)
}

#[test]
fn malformed_wire_input_is_typed_and_leaks_nothing() {
    // Every malformed request maps to a typed 4xx/5xx, never a panic, a
    // hung ticket, or a leaked admission slot — and none of it is ever
    // *offered*, so the report identity only counts the good query.
    let server = native_server();
    let handle = server.handle();
    // Short read timeout so the truncated-body case answers 408 fast.
    let cfg = WireCfg {
        read_timeout: Duration::from_millis(200),
        max_body_bytes: 64 * 1024,
        ..WireCfg::default()
    };
    let wire = start_wire(&server, cfg);
    let addr = wire.local_addr().to_string();

    // Body-level rejections over one keep-alive connection.
    let mut conn = WireConn::connect(&addr).unwrap();
    for (body, want) in [
        ("{nope", 400),                                          // malformed JSON
        ("{\"items\": 3}", 400),                                 // missing model
        ("{\"model\": \"nope\", \"items\": 3}", 404),            // unknown model
        ("{\"model\": \"rmc1-small\", \"items\": 0}", 400),      // zero items
        ("{\"model\": \"rmc1-small\", \"items\": 9999999}", 400), // over item cap
        ("[]", 400),                                             // not an object
    ] {
        let (status, resp) = conn.request("POST", "/v1/query", Some(body)).unwrap();
        assert_eq!(status, want, "body {body}: {resp}");
        let parsed = Json::parse(&resp).unwrap();
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("wire_error/v1"));
    }
    // Method/path errors on the same connection.
    let (status, _) = conn.request("GET", "/v1/query", None).unwrap();
    assert_eq!(status, 405);
    let (status, _) = conn.request("GET", "/v1/nothing", None).unwrap();
    assert_eq!(status, 404);

    // Framing-level rejections (fresh sockets; server closes after).
    let (status, _) = raw_post_query(&addr, &[0x7b, 0xff, 0xfe, 0x7d]);
    assert_eq!(status, 400, "non-UTF8 body");
    let (status, _) = raw_roundtrip(
        &addr,
        b"POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: 99999999\r\n\r\n",
    );
    assert_eq!(status, 413, "oversized Content-Length rejected without reading the body");
    let (status, _) = raw_roundtrip(
        &addr,
        b"POST /v1/query HTTP/1.1\r\nHost: t\r\nContent-Length: 100\r\n\r\n{\"model\":",
    );
    assert_eq!(status, 408, "truncated body times out with a typed error");
    let (status, _) = raw_roundtrip(&addr, b"GARBAGE REQUEST LINE EXTRA WORDS HERE\r\n\r\n");
    assert_eq!(status, 400, "malformed request line");
    let (status, _) = raw_roundtrip(
        &addr,
        b"POST /v1/query HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
    );
    assert_eq!(status, 501, "chunked framing is refused, not misparsed");

    // Nothing above touched admission control or the ticket table.
    assert_eq!(handle.inflight(), 0, "malformed traffic leaked an admission slot");

    // The server still serves: one good query (fresh connection — the
    // 200ms idle timeout has long since closed the keep-alive one),
    // then the report counts exactly that one offered/completed query.
    let good = "{\"model\": \"rmc1-small\", \"items\": 2, \"id\": 1}";
    let mut conn = WireConn::connect(&addr).unwrap();
    let (status, resp) = conn.request("POST", "/v1/query", Some(good)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let parsed = Json::parse(&resp).unwrap();
    assert_eq!(parsed.get("outcome").and_then(Json::as_str), Some("completed"));
    assert!(handle.quiesce(Duration::from_secs(10)).unwrap());
    let report = handle.report().unwrap();
    assert_eq!(report.queries_offered, 1, "only the good query was ever offered");
    assert_eq!(report.queries, 1);
    assert_eq!(report.queries_shed, 0);
    assert_eq!(report.queries_failed, 0);
    let (_h2, h4, _h5) = wire.response_counts();
    assert!(h4 >= 10, "the rejections above were all counted as 4xx (got {h4})");
}

#[test]
fn keep_alive_session_and_report_schema() {
    // One connection carries many requests; GET /v1/report answers the
    // live schema-tagged report between queries.
    let server = native_server();
    let wire = start_wire(&server, WireCfg::default());
    let mut conn = WireConn::connect(&wire.local_addr().to_string()).unwrap();
    for id in 0..5u64 {
        let body = format!("{{\"model\": \"rmc1-small\", \"items\": 2, \"id\": {id}}}");
        let (status, resp) = conn.request("POST", "/v1/query", Some(&body)).unwrap();
        assert_eq!(status, 200, "query {id}: {resp}");
        let parsed = Json::parse(&resp).unwrap();
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("wire_query/v1"));
        assert_eq!(parsed.get("id").and_then(Json::as_f64), Some(id as f64));
    }
    let (status, resp) = conn.request("GET", "/v1/report", None).unwrap();
    assert_eq!(status, 200);
    let report = Json::parse(&resp).unwrap();
    assert_eq!(report.get("schema").and_then(Json::as_str), Some(SERVE_REPORT_SCHEMA));
    assert_eq!(report.get("queries_completed").and_then(Json::as_f64), Some(5.0));
    let (status, resp) = conn.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(status, 200, "{resp}");
}

#[test]
fn quiesce_endpoint_drains_and_raises_the_exit_flag() {
    let server = native_server();
    let wire = start_wire(&server, WireCfg::default());
    let addr = wire.local_addr().to_string();
    let mut conn = WireConn::connect(&addr).unwrap();
    let (status, _) = conn
        .request("POST", "/v1/query", Some("{\"model\": \"rmc2-small\", \"items\": 3}"))
        .unwrap();
    assert_eq!(status, 200);
    assert!(!wire.quiesce_requested(), "flag must not be up before any quiesce");
    let (status, resp) = conn.request("POST", "/v1/quiesce", Some("{}")).unwrap();
    assert_eq!(status, 200, "{resp}");
    let parsed = Json::parse(&resp).unwrap();
    assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("quiesce/v1"));
    assert_eq!(parsed.get("drained").and_then(Json::as_bool), Some(true));
    let report = parsed.get("report").unwrap();
    assert_eq!(report.get("schema").and_then(Json::as_str), Some(SERVE_REPORT_SCHEMA));
    assert_eq!(report.get("queries_completed").and_then(Json::as_f64), Some(1.0));
    assert!(wire.quiesce_requested(), "the serve CLI polls this flag to exit");
}

#[test]
fn overload_sheds_as_429_with_exact_wire_accounting() {
    // A capped server under a socket-side flood: sheds surface as 429,
    // completions as 200, and the wire-side tallies reconcile exactly
    // with the server report — the accounting identity crosses the wire.
    let server = ServerBuilder::new()
        .mix(TrafficMix::parse(MIX).unwrap())
        .workers(2)
        .routing("least-loaded")
        .sla_ms(50.0)
        .buckets(vec![1, 8])
        .backend(Arc::new(MockBackend { latency: Duration::from_millis(10) }))
        .inflight_cap(1)
        .build()
        .unwrap();
    let wire = start_wire(&server, WireCfg::default());
    let addr = wire.local_addr().to_string();
    let (clients, per_client) = (4usize, 30usize);
    let counts: Vec<(u64, u64, u64)> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut conn = WireConn::connect(&addr).unwrap();
                    let (mut ok, mut shed, mut other) = (0u64, 0u64, 0u64);
                    for i in 0..per_client {
                        let id = (c * per_client + i) as u64;
                        let model = if id % 2 == 0 { "rmc1-small" } else { "rmc2-small" };
                        let body =
                            format!("{{\"model\": \"{model}\", \"items\": 2, \"id\": {id}}}");
                        let (status, _) =
                            conn.request("POST", "/v1/query", Some(&body)).unwrap();
                        match status {
                            200 => ok += 1,
                            429 => shed += 1,
                            _ => other += 1,
                        }
                    }
                    (ok, shed, other)
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let ok: u64 = counts.iter().map(|c| c.0).sum();
    let shed: u64 = counts.iter().map(|c| c.1).sum();
    let other: u64 = counts.iter().map(|c| c.2).sum();
    let offered = (clients * per_client) as u64;
    assert_eq!(ok + shed, offered, "every query answered 200 or 429");
    assert_eq!(other, 0);
    assert!(shed > 0, "a cap-1 flood must shed");

    let handle = server.handle();
    assert!(handle.quiesce(Duration::from_secs(20)).unwrap());
    let report = handle.report().unwrap();
    assert_eq!(report.queries_offered, offered);
    assert_eq!(report.queries, ok, "wire 200s == report completions");
    assert_eq!(report.queries_shed, shed, "wire 429s == report sheds");
    assert_eq!(report.queries_failed, 0);
    let tenant_shed: u64 = report.per_tenant.iter().map(|t| t.shed_queries).sum();
    assert_eq!(tenant_shed, shed, "per-tenant shed accounting intact across the wire");
}
