//! Property-based tests (std-only `util::prop` harness — proptest is
//! unavailable offline) on the coordinator and substrate invariants:
//! batcher conservation, router eligibility, cache bounds, inclusive-
//! hierarchy containment, JSON round-trips, SLS padding algebra, and
//! the quantization contracts (cross-dtype CTR error bounds, per-dtype
//! bitwise determinism, SIMD-toggle bitwise invisibility).

use std::collections::HashSet;
use std::time::{Duration, Instant};

use recsys::config::{CacheInclusion, RmcConfig, ServerGen, ServerSpec, PJRT_BATCHES};
use recsys::coordinator::{DynamicBatcher, RoutingPolicy, WorkerInfo};
use recsys::metrics::LatencyHistogram;
use recsys::runtime::{
    golden_dense, golden_ids, golden_lwts, set_simd_enabled, simd_available, Engine, EngineKind,
    ExecOptions, NativeModel, ScratchArena, ShardedEmbeddingService, TableDtype,
};
use recsys::simulator::{Cache, SharedMemorySystem};
use recsys::util::prop::{check, f64_in, pick, usize_in};
use recsys::util::{Json, Rng};
use recsys::workload::Query;

// ------------------------------------------------------------ batcher --
#[test]
fn prop_batcher_conserves_queries() {
    // Every pushed query comes out exactly once, in exactly one batch,
    // and every batch respects bucket >= min(items, max_batch).
    check("batcher-conservation", 60, |rng, _| {
        let buckets = vec![1usize, 8, 32, 128];
        let max_batch = *pick(rng, &[8usize, 32, 128]);
        let mut b = DynamicBatcher::new(buckets.clone(), max_batch, Duration::from_millis(1));
        let now = Instant::now();
        let n = usize_in(rng, 1, 60);
        let models = ["a", "b", "c"];
        let mut pushed = HashSet::new();
        let mut batches = Vec::new();
        for id in 0..n as u64 {
            let items = usize_in(rng, 1, 12);
            let model = *pick(rng, &models);
            pushed.insert(id);
            if let Some(batch) = b.push(Query::new(id, model, items, 0.0), now) {
                batches.push(batch);
            }
        }
        batches.extend(b.drain(now));
        let mut seen = HashSet::new();
        for batch in &batches {
            assert!(buckets.contains(&batch.bucket), "bucket {} unknown", batch.bucket);
            assert!(batch.bucket <= max_batch);
            for q in &batch.queries {
                assert_eq!(q.model, batch.model, "model purity violated");
                assert!(seen.insert(q.id), "query {} duplicated", q.id);
            }
        }
        assert_eq!(seen, pushed, "queries lost: {:?}", pushed.difference(&seen));
        assert_eq!(b.pending_items(), 0);
    });
}

#[test]
fn prop_bucket_is_minimal_cover() {
    check("bucket-minimal", 100, |rng, _| {
        let b = DynamicBatcher::new(vec![1, 8, 32, 128], 128, Duration::from_millis(1));
        let n = usize_in(rng, 1, 128);
        let bucket = b.bucket_for(n);
        assert!(bucket >= n);
        // No smaller AOT'd bucket also covers n.
        for smaller in [1usize, 8, 32, 128] {
            if smaller < bucket {
                assert!(smaller < n, "bucket {bucket} not minimal for {n}");
            }
        }
    });
}

// ------------------------------------------------------------- router --
#[test]
fn prop_router_picks_eligible_worker() {
    check("router-eligible", 80, |rng, _| {
        let n_workers = usize_in(rng, 1, 8);
        let gens = [ServerGen::Haswell, ServerGen::Broadwell, ServerGen::Skylake];
        let workers: Vec<WorkerInfo> = (0..n_workers)
            .map(|id| WorkerInfo {
                id,
                gen: *pick(rng, &gens),
                models: if rng.gen_bool(0.3) { vec!["special".into()] } else { vec![] },
            })
            .collect();
        let outstanding: Vec<usize> =
            (0..n_workers).map(|_| usize_in(rng, 0, 5)).collect();
        let alive: Vec<bool> = (0..n_workers).map(|_| rng.gen_bool(0.8)).collect();
        let policy = *pick(
            rng,
            &[RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::Heterogeneity],
        );
        let model = *pick(rng, &["special", "other"]);
        let bucket = *pick(rng, &[1usize, 8, 32, 128]);
        let mut rr = usize_in(rng, 0, 100);
        match policy.pick(&workers, model, bucket, &outstanding, &alive, &mut rr) {
            Some(id) => {
                let w = &workers[id];
                assert!(alive[id], "picked a dead worker");
                assert!(w.models.is_empty() || w.models.iter().any(|m| m == model));
            }
            None => {
                // Only legal if nobody alive serves the model.
                assert!(workers.iter().all(|w| !alive[w.id]
                    || (!w.models.is_empty() && !w.models.iter().any(|m| m == model))));
            }
        }
    });
}

// -------------------------------------------------------------- cache --
#[test]
fn prop_cache_occupancy_and_inclusion() {
    check("cache-bounds", 40, |rng, _| {
        let ways = *pick(rng, &[1usize, 2, 4, 8]);
        let lines = usize_in(rng, ways, 128);
        let mut c = Cache::new((lines * 64) as u64, ways);
        let universe = usize_in(rng, 1, 4000) as u64;
        for _ in 0..2000 {
            let line = rng.gen_range(universe);
            if !c.probe(line) {
                c.insert(line);
            }
            // A just-inserted line is present.
            assert!(c.contains(line));
        }
        assert!(c.occupancy() * 64 <= c.capacity_bytes() as usize);
    });
}

#[test]
fn prop_inclusive_hierarchy_containment() {
    // Inclusive invariant: after any access stream, an L2-resident line
    // serves without reaching DRAM (it was installed in L3 too, and L3
    // eviction would have back-invalidated it).
    check("inclusive-containment", 12, |rng, _| {
        let mut spec = ServerSpec::broadwell();
        spec.l1_kb = 1;
        spec.l2_kb = 4;
        spec.l3_mb = 0.0078125; // 8KB = 128 lines
        spec.inclusion = CacheInclusion::Inclusive;
        let insts = usize_in(rng, 1, 3);
        let mut mem = SharedMemorySystem::new(&spec, insts);
        let mut recent: Vec<(usize, u64)> = Vec::new();
        for _ in 0..3000 {
            let inst = usize_in(rng, 0, insts - 1);
            let addr = rng.gen_range(1 << 14) * 64;
            mem.access(inst, addr);
            recent.push((inst, addr));
            if recent.len() > 4 {
                recent.remove(0);
            }
            // Immediately re-accessing the most recent line never goes to
            // DRAM (it is in L1).
            let (i2, a2) = *recent.last().unwrap();
            let lvl = mem.access(i2, a2);
            assert!(
                lvl == recsys::simulator::HitLevel::L1,
                "immediate re-access must hit L1, got {lvl:?}"
            );
        }
    });
}

// ---------------------------------------------------------- histogram --
#[test]
fn prop_quantiles_monotone_and_bounded() {
    check("quantiles", 60, |rng, _| {
        let mut h = LatencyHistogram::new();
        let n = usize_in(rng, 1, 300);
        for _ in 0..n {
            h.record(f64_in(rng, 0.0, 1000.0));
        }
        let (min, p5, p50, p99, max) = (h.min(), h.p5(), h.p50(), h.p99(), h.max());
        assert!(min <= p5 && p5 <= p50 && p50 <= p99 && p99 <= max);
        assert!(h.mean() >= min && h.mean() <= max);
    });
}

// --------------------------------------------------------------- json --
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_bool(0.5)),
            2 => Json::Num((rng.gen_f64() * 2000.0 - 1000.0).round()),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.gen_range(1000))),
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for k in 0..rng.gen_range(4) {
                    m.insert(format!("k{k}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check("json-roundtrip", 80, |rng, _| {
        let v = random_json(rng, 3);
        let text = v.to_string_pretty();
        let parsed = Json::parse(&text).expect("reparse");
        assert_eq!(parsed, v, "round-trip failed for {text}");
    });
}

// ------------------------------------------------------------ arrival --
#[test]
fn prop_arrivals_sorted_positive() {
    check("arrivals", 40, |rng, _| {
        let rate = f64_in(rng, 1.0, 5000.0);
        let mut arr = recsys::workload::PoissonArrivals::new(rate, rng.next_u64());
        let mut prev = 0.0;
        for _ in 0..200 {
            let t = arr.next_arrival_s();
            assert!(t > prev);
            prev = t;
        }
    });
}

// -------------------------------------------------------- exec engine --
fn rmc_inputs(cfg: &RmcConfig, batch: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    (
        golden_dense(batch, cfg.dense_dim),
        golden_ids(cfg.num_tables, batch, cfg.lookups, cfg.pjrt_rows),
        golden_lwts(cfg.num_tables, batch, cfg.lookups),
    )
}

#[test]
fn prop_parallel_serial_bit_identical_all_presets() {
    // The engine determinism contract (DESIGN.md §2): serial and 2/4/8-
    // thread optimized runs must agree bitwise on every model preset —
    // shard boundaries partition output elements, they never split a
    // reduction.
    let serial = Engine::serial();
    let engines: Vec<Engine> = [2usize, 4, 8]
        .into_iter()
        .map(|threads| Engine::new(ExecOptions { threads, ..Default::default() }))
        .collect();
    for cfg in recsys::config::all_rmc() {
        let m = NativeModel::new(&cfg, 13);
        let (dense, ids, lwts) = rmc_inputs(&cfg, 3);
        let mut arena = ScratchArena::new();
        let want = m.run_rmc_with(&serial, &mut arena, &dense, &ids, &lwts).unwrap();
        for e in &engines {
            let got = m.run_rmc_with(e, &mut arena, &dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "{}: t={} diverged from serial", cfg.name, e.threads());
        }
    }
}

#[test]
fn prop_parallel_serial_bit_identical_batch_buckets() {
    // Same contract across every AOT batch bucket (the sizes the dynamic
    // batcher actually emits), through reused arenas on both sides.
    let cfg = recsys::config::rmc1_small();
    let m = NativeModel::new(&cfg, 7);
    let serial = Engine::serial();
    let par = Engine::new(ExecOptions { threads: 4, ..Default::default() });
    let mut a1 = ScratchArena::new();
    let mut a2 = ScratchArena::new();
    for &batch in PJRT_BATCHES.iter() {
        let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
        let want = m.run_rmc_with(&serial, &mut a1, &dense, &ids, &lwts).unwrap();
        let got = m.run_rmc_with(&par, &mut a2, &dense, &ids, &lwts).unwrap();
        assert_eq!(want, got, "bucket {batch} diverged");
    }
}

#[test]
fn prop_parallel_serial_bit_identical_random_batches() {
    // Randomized batches (including non-bucket, non-multiple-of-tile
    // sizes) keep the bitwise guarantee.
    let cfg = recsys::config::rmc1_small();
    let m = NativeModel::new(&cfg, 3);
    let serial = Engine::serial();
    let par2 = Engine::new(ExecOptions { threads: 2, ..Default::default() });
    let par8 = Engine::new(ExecOptions { threads: 8, ..Default::default() });
    let mut arena = ScratchArena::new();
    check("engine-bit-equivalence", 10, |rng, _| {
        let batch = usize_in(rng, 1, 17);
        let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
        let want = m.run_rmc_with(&serial, &mut arena, &dense, &ids, &lwts).unwrap();
        for e in [&par2, &par8] {
            let got = m.run_rmc_with(e, &mut arena, &dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "b{batch} t={} diverged", e.threads());
        }
    });
}

#[test]
fn prop_padding_invariance_survives_arena_reuse() {
    // Pollute an arena with a big batch, then assert (a) b1 equals slot 0
    // of a weight-0-padded b8 run and (b) the reused-arena b1 equals a
    // fresh-arena b1 — all bitwise, under 4-thread parallel shards.
    // Stale scratch must never leak into a fresh batch.
    let cfg = recsys::config::rmc1_small();
    let m = NativeModel::new(&cfg, 21);
    let par = Engine::new(ExecOptions { threads: 4, ..Default::default() });
    let mut arena = ScratchArena::new();
    let (dense32, ids32, lwts32) = rmc_inputs(&cfg, 32);
    m.run_rmc_with(&par, &mut arena, &dense32, &ids32, &lwts32).unwrap();

    let (dense1, ids1, lwts1) = rmc_inputs(&cfg, 1);
    let out1 = m.run_rmc_with(&par, &mut arena, &dense1, &ids1, &lwts1).unwrap();

    let (t, l, d) = (cfg.num_tables, cfg.lookups, cfg.dense_dim);
    let b = 8usize;
    let mut dense8 = vec![0.0f32; b * d];
    dense8[..d].copy_from_slice(&dense1);
    let mut ids8 = vec![0i32; t * b * l];
    let mut lwts8 = vec![0.0f32; t * b * l];
    for table in 0..t {
        for j in 0..l {
            ids8[(table * b) * l + j] = ids1[table * l + j];
            lwts8[(table * b) * l + j] = 1.0;
        }
    }
    let out8 = m.run_rmc_with(&par, &mut arena, &dense8, &ids8, &lwts8).unwrap();
    assert_eq!(out1[0], out8[0], "padding slots leaked into slot 0");

    let fresh = m.run_rmc_with(&par, &mut ScratchArena::new(), &dense1, &ids1, &lwts1).unwrap();
    assert_eq!(out1, fresh, "arena reuse changed numerics");
}

#[test]
fn prop_reference_and_optimized_agree() {
    // The two engines differ only in FP summation order; CTRs must match
    // to tight tolerance sample-by-sample.
    let cfg = recsys::config::rmc1_small();
    let m = NativeModel::new(&cfg, 9);
    let reference = Engine::new(ExecOptions {
        threads: 1,
        engine: EngineKind::Reference,
        ..Default::default()
    });
    let mut arena = ScratchArena::new();
    let (dense, ids, lwts) = rmc_inputs(&cfg, 8);
    let a = m.run_rmc_with(&reference, &mut arena, &dense, &ids, &lwts).unwrap();
    let b = m.run_rmc(&dense, &ids, &lwts).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!((x - y).abs() < 1e-4, "sample {i}: reference {x} vs optimized {y}");
    }
}

#[test]
fn prop_multi_tenant_shared_engine_determinism() {
    // The co-location contract: multiple tenants' models interleaved
    // through ONE shared parallel engine, reusing one scratch arena
    // across models (exactly what a coordinator worker does under a
    // multi-model mix), must reproduce the serial per-model outputs
    // bitwise — no batch of tenant A may perturb tenant B's numerics.
    let cfg1 = recsys::config::rmc1_small();
    let cfg2 = recsys::config::rmc2_small();
    let m1 = NativeModel::new(&cfg1, 13);
    let m2 = NativeModel::new(&cfg2, 13);
    let serial = Engine::serial();
    let shared = Engine::new(ExecOptions { threads: 4, ..Default::default() });
    let batches = [1usize, 8, 32];

    // Serial goldens, fresh arena per run.
    let golden = |m: &NativeModel, cfg: &RmcConfig, batch: usize| {
        let (dense, ids, lwts) = rmc_inputs(cfg, batch);
        m.run_rmc_with(&serial, &mut ScratchArena::new(), &dense, &ids, &lwts).unwrap()
    };
    let want1: Vec<Vec<f32>> = batches.iter().map(|&b| golden(&m1, &cfg1, b)).collect();
    let want2: Vec<Vec<f32>> = batches.iter().map(|&b| golden(&m2, &cfg2, b)).collect();

    // Interleave tenants through the shared engine + one reused arena,
    // in alternating order across two rounds.
    let mut arena = ScratchArena::new();
    for round in 0..2 {
        for (i, &batch) in batches.iter().enumerate() {
            let order: [(&NativeModel, &RmcConfig, &[f32]); 2] = if (round + i) % 2 == 0 {
                [(&m1, &cfg1, &want1[i]), (&m2, &cfg2, &want2[i])]
            } else {
                [(&m2, &cfg2, &want2[i]), (&m1, &cfg1, &want1[i])]
            };
            for (m, cfg, want) in order {
                let (dense, ids, lwts) = rmc_inputs(cfg, batch);
                let got = m.run_rmc_with(&shared, &mut arena, &dense, &ids, &lwts).unwrap();
                assert_eq!(
                    got.as_slice(),
                    want,
                    "{} b{batch} diverged under shared-engine interleaving (round {round})",
                    cfg.name
                );
            }
        }
    }
}

// ------------------------------------------------------- sharded exec --
#[test]
fn prop_sharded_conformance_bitwise_across_presets() {
    // The scale-out determinism contract (ISSUE 4 / DESIGN.md §2): for
    // every model preset, shard counts {1, 2, 4}, with and without the
    // leader hot-row cache, the ShardedEmbeddingService output is
    // bitwise-equal to single-node run_rmc — on deterministic batch
    // sizes, on randomized batch sizes, and on a repeated batch (warm
    // cache, rows served from the leader instead of the shards).
    // Small presets keep tier-1 (debug-mode) model-build time sane
    // while still covering all three RMC classes.
    for cfg in [
        recsys::config::rmc1_small(),
        recsys::config::rmc2_small(),
        recsys::config::rmc3_small(),
    ] {
        let single = NativeModel::new(&cfg, 31);
        for shards in [1usize, 2, 4] {
            for cache_rows in [0.0f64, 0.05] {
                let svc = ShardedEmbeddingService::new(
                    &cfg,
                    31,
                    ExecOptions { shards, cache_rows, ..Default::default() },
                )
                .unwrap();
                let mut arena = ScratchArena::new();
                for &batch in &[1usize, 3, 8] {
                    let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
                    let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
                    let got = svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                    assert_eq!(
                        want.as_slice(),
                        got,
                        "{} shards={shards} cache={cache_rows} b{batch} diverged",
                        cfg.name
                    );
                }
                // Randomized batch sizes through the same (reused)
                // arena and (warm) cache.
                check("sharded-conformance", 4, |rng, _| {
                    let batch = usize_in(rng, 1, 13);
                    let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
                    let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
                    let got = svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                    assert_eq!(
                        want.as_slice(),
                        got,
                        "{} shards={shards} cache={cache_rows} random b{batch} diverged",
                        cfg.name
                    );
                });
                // Repeat one batch: with the cache enabled every row is
                // now leader-resident — bits must not move.
                let (dense, ids, lwts) = rmc_inputs(&cfg, 8);
                let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
                for round in 0..2 {
                    let got = svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                    assert_eq!(
                        want.as_slice(),
                        got,
                        "{} shards={shards} cache={cache_rows} warm round {round} diverged",
                        cfg.name
                    );
                }
                if cache_rows > 0.0 {
                    let stats = svc.stats();
                    assert!(
                        stats.cache_hits > 0,
                        "{} shards={shards}: warm repeats must hit the row cache",
                        cfg.name
                    );
                }
            }
        }
    }
}

// ------------------------------------------------- quantization/simd --
#[test]
fn prop_quantized_forward_tracks_f32_all_presets() {
    // The ISSUE 8 accuracy contract: int8/f16 row storage perturbs the
    // CTR by at most a documented bound vs the f32 model on EVERY
    // preset — the f32 model stays the accuracy oracle, and
    // quantization error is a measured, bounded quantity, never silent
    // drift. Bounds match the unit test in runtime::native (int8
    // carries per-row scale/bias; f16 has ~3 decimal digits).
    for cfg in recsys::config::all_rmc() {
        let f32m = NativeModel::new(&cfg, 11);
        let (dense, ids, lwts) = rmc_inputs(&cfg, 6);
        let want = f32m.run_rmc(&dense, &ids, &lwts).unwrap();
        for (dtype, bound) in [(TableDtype::F16, 5e-3f32), (TableDtype::Int8, 0.05)] {
            let qm = NativeModel::with_dtype(&cfg, 11, dtype);
            let got = qm.run_rmc(&dense, &ids, &lwts).unwrap();
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (w - g).abs() <= bound,
                    "{} sample {i}: f32 CTR {w} vs {} CTR {g} exceeds bound {bound}",
                    cfg.name,
                    dtype.name()
                );
            }
        }
    }
}

#[test]
fn prop_quantized_bitwise_determinism_per_dtype() {
    // The determinism contract is PER DTYPE: for each storage encoding,
    // serial == 4-thread optimized bitwise, and the sharded service ==
    // single-node bitwise (cache cold and warm) — quantization changes
    // which bytes a gather streams, never which bits an execution plan
    // yields for the same stored bytes.
    let cfg = recsys::config::rmc1_small();
    let serial = Engine::serial();
    let par = Engine::new(ExecOptions { threads: 4, ..Default::default() });
    for dtype in [TableDtype::F32, TableDtype::F16, TableDtype::Int8] {
        let m = NativeModel::with_dtype(&cfg, 19, dtype);
        let mut arena = ScratchArena::new();
        for &batch in &[1usize, 3, 8] {
            let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
            let want =
                m.run_rmc_with(&serial, &mut ScratchArena::new(), &dense, &ids, &lwts).unwrap();
            let got = m.run_rmc_with(&par, &mut arena, &dense, &ids, &lwts).unwrap();
            assert_eq!(want, got, "{} b{batch}: parallel diverged from serial", dtype.name());
        }
        for cache_rows in [0.0f64, 0.05] {
            let svc = ShardedEmbeddingService::new(
                &cfg,
                19,
                ExecOptions { shards: 2, cache_rows, dtype, ..Default::default() },
            )
            .unwrap();
            let (dense, ids, lwts) = rmc_inputs(&cfg, 5);
            let want = m.run_rmc(&dense, &ids, &lwts).unwrap();
            for round in 0..2 {
                let got = svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                assert_eq!(
                    want.as_slice(),
                    got,
                    "{} cache={cache_rows} round {round}: sharded diverged from single-node",
                    dtype.name()
                );
            }
        }
    }
}

#[test]
fn prop_simd_toggle_is_bitwise_invisible() {
    // The AVX2 kernels are constructed bitwise-identical to the scalar
    // optimized path (unfused mul + add, identical order): forcing the
    // SIMD path off and on around whole forwards must not move a single
    // bit, for every storage dtype. Auto-skips (with a log line) on
    // hosts without AVX2/FMA/F16C.
    if !simd_available() {
        println!("prop_simd_toggle_is_bitwise_invisible: AVX2/FMA/F16C absent; skipping");
        return;
    }
    let cfg = recsys::config::rmc1_small();
    let par = Engine::new(ExecOptions { threads: 4, ..Default::default() });
    let prev = set_simd_enabled(false);
    for dtype in [TableDtype::F32, TableDtype::F16, TableDtype::Int8] {
        let m = NativeModel::with_dtype(&cfg, 41, dtype);
        let (dense, ids, lwts) = rmc_inputs(&cfg, 7);
        set_simd_enabled(false);
        let scalar =
            m.run_rmc_with(&par, &mut ScratchArena::new(), &dense, &ids, &lwts).unwrap();
        set_simd_enabled(true);
        let simd = m.run_rmc_with(&par, &mut ScratchArena::new(), &dense, &ids, &lwts).unwrap();
        assert_eq!(scalar, simd, "{}: toggling SIMD moved the bits", dtype.name());
    }
    set_simd_enabled(prev);
}

// ---------------------------------------------------------- placement --
#[test]
fn prop_placement_conformance_bitwise_across_presets() {
    // The ISSUE 6 tentpole contract: ANY valid placement — row-range
    // split, hot-table replica sets, planner-produced or adversarially
    // random — must serve bits identical to single-node execution, at
    // any shard count, cache on or off. Placement moves bytes and
    // routing, never numerics.
    use recsys::runtime::{Placement, PlacementMode, RowSegment, TablePlacement};

    // Planner-produced plans over the preset grid.
    for cfg in [
        recsys::config::rmc1_small(),
        recsys::config::rmc2_small(),
        recsys::config::rmc3_small(),
    ] {
        let single = NativeModel::new(&cfg, 17);
        for mode in [PlacementMode::Rows, PlacementMode::Auto] {
            for shards in [1usize, 2, 4] {
                for (cache_rows, replicate_hot) in [(0.0f64, 0.0), (0.05, 0.3)] {
                    let svc = ShardedEmbeddingService::new(
                        &cfg,
                        17,
                        ExecOptions {
                            shards,
                            cache_rows,
                            placement: mode,
                            replicate_hot,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let mut arena = ScratchArena::new();
                    for &batch in &[1usize, 5, 8] {
                        let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
                        let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
                        // Twice: cold, then warm (cache contents and
                        // replica load counters have state now).
                        for round in 0..2 {
                            let got =
                                svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                            assert_eq!(
                                want.as_slice(),
                                got,
                                "{} {:?} shards={shards} cache={cache_rows} \
                                 rep={replicate_hot} b{batch} round {round} diverged",
                                cfg.name,
                                mode
                            );
                        }
                    }
                }
            }
        }
    }

    // Adversarially random explicit plans on one preset: random cut
    // points, random segment owners, random replica subsets.
    let cfg = recsys::config::rmc1_small();
    let single = NativeModel::new(&cfg, 23);
    let rows = single.rows();
    let mut arena = ScratchArena::new();
    check("placement-conformance", 8, |rng, _| {
        let shards = usize_in(rng, 1, 4);
        let tables = (0..cfg.num_tables)
            .map(|_| {
                if rng.gen_bool(0.4) {
                    let mut reps: Vec<usize> =
                        (0..shards).filter(|_| rng.gen_bool(0.5)).collect();
                    if reps.is_empty() {
                        reps.push(usize_in(rng, 0, shards - 1));
                    }
                    TablePlacement::Replicated(reps)
                } else {
                    let mut cuts: Vec<usize> = (0..usize_in(rng, 0, 2))
                        .map(|_| usize_in(rng, 1, rows - 1))
                        .collect();
                    cuts.sort_unstable();
                    cuts.dedup();
                    let mut segs = Vec::new();
                    let mut lo = 0usize;
                    for hi in cuts.into_iter().chain([rows]) {
                        segs.push(RowSegment {
                            shard: usize_in(rng, 0, shards - 1),
                            rows: (lo, hi),
                        });
                        lo = hi;
                    }
                    TablePlacement::Split(segs)
                }
            })
            .collect();
        let plan = Placement { shards, tables };
        let cache_rows = *pick(rng, &[0.0f64, 0.08]);
        let svc = ShardedEmbeddingService::with_plan(
            &cfg,
            23,
            ExecOptions { cache_rows, ..Default::default() },
            plan,
        )
        .unwrap();
        for batch in [1usize, 7] {
            let (dense, ids, lwts) = rmc_inputs(&cfg, batch);
            let want = single.run_rmc(&dense, &ids, &lwts).unwrap();
            for round in 0..2 {
                let got = svc.run_rmc_into(&mut arena, &dense, &ids, &lwts).unwrap();
                assert_eq!(
                    want.as_slice(),
                    got,
                    "random plan shards={shards} cache={cache_rows} b{batch} \
                     round {round} diverged"
                );
            }
        }
    });
}

// ------------------------------------------------------------ faults --
#[test]
fn prop_replica_failover_bitwise() {
    // The ISSUE 7 failover contract: when every table is replicated on
    // at least two shards, killing ANY single shard must not change a
    // single bit of the output — replicated reads silently fail over to
    // a surviving replica, cache on or off, and a restart returns the
    // service to full health with the same bits. Random shard counts,
    // random replica subsets, random victim.
    use recsys::runtime::{Placement, TablePlacement};

    let cfg = recsys::config::rmc1_small();
    let single = NativeModel::new(&cfg, 29);
    let mut arena = ScratchArena::new();
    check("replica-failover", 8, |rng, _| {
        let shards = usize_in(rng, 2, 4);
        let tables = (0..cfg.num_tables)
            .map(|_| {
                let mut reps: Vec<usize> = (0..shards).filter(|_| rng.gen_bool(0.6)).collect();
                while reps.len() < 2 {
                    let s = usize_in(rng, 0, shards - 1);
                    if !reps.contains(&s) {
                        reps.push(s);
                    }
                }
                reps.sort_unstable();
                TablePlacement::Replicated(reps)
            })
            .collect();
        let plan = Placement { shards, tables };
        let cache_rows = *pick(rng, &[0.0f64, 0.08]);
        let svc = ShardedEmbeddingService::with_plan(
            &cfg,
            29,
            ExecOptions { cache_rows, ..Default::default() },
            plan,
        )
        .unwrap();
        let victim = usize_in(rng, 0, shards - 1);

        let batches: Vec<_> = [1usize, 6].iter().map(|&b| rmc_inputs(&cfg, b)).collect();
        // Healthy baseline: conforms to single-node (and warms the cache
        // so the kill exercises cached + failover paths together).
        for (dense, ids, lwts) in &batches {
            let want = single.run_rmc(dense, ids, lwts).unwrap();
            let got = svc.run_rmc_into(&mut arena, dense, ids, lwts).unwrap();
            assert_eq!(want.as_slice(), got, "healthy run diverged (shards={shards})");
        }

        assert!(svc.kill_shard(victim), "first kill of a live shard applies");
        assert!(!svc.kill_shard(victim), "killing a dead shard is a no-op");
        assert_eq!(svc.stats().shards_alive, shards - 1);
        for (dense, ids, lwts) in &batches {
            let want = single.run_rmc(dense, ids, lwts).unwrap();
            let got = svc.run_rmc_into(&mut arena, dense, ids, lwts).unwrap();
            assert_eq!(
                want.as_slice(),
                got,
                "failover run diverged (shards={shards} victim={victim} cache={cache_rows})"
            );
        }

        assert!(svc.restart_shard(victim).unwrap(), "restart re-materializes the victim");
        assert_eq!(svc.stats().shards_alive, shards);
        for (dense, ids, lwts) in &batches {
            let want = single.run_rmc(dense, ids, lwts).unwrap();
            let got = svc.run_rmc_into(&mut arena, dense, ids, lwts).unwrap();
            assert_eq!(want.as_slice(), got, "post-restart run diverged (shards={shards})");
        }
        let stats = svc.stats();
        assert_eq!(stats.shard_deaths, 1);
        assert_eq!(stats.shard_restarts, 1);
    });
}

// ------------------------------------------------------------- id gen --
#[test]
fn prop_idgen_in_range_and_deterministic() {
    use recsys::workload::{IdDistribution, SparseIdGen};
    check("idgen", 50, |rng, _| {
        let rows = usize_in(rng, 1, 100_000);
        let dist = match rng.gen_range(3) {
            0 => IdDistribution::Uniform,
            1 => IdDistribution::Zipf { s: f64_in(rng, 0.3, 1.5) },
            _ => IdDistribution::Trace {
                hot_fraction: f64_in(rng, 0.0005, 0.1),
                hot_prob: f64_in(rng, 0.1, 0.99),
            },
        };
        let seed = rng.next_u64();
        let mut a = SparseIdGen::new(dist, rows, seed);
        let mut b = SparseIdGen::new(dist, rows, seed);
        let va = a.gen_batch(4, 16);
        let vb = b.gen_batch(4, 16);
        assert_eq!(va, vb);
        assert!(va.iter().all(|&id| (id as usize) < rows));
    });
}
